//! Evaluation coordinator — the L3 orchestrator that drives the paper's
//! experiment matrix (50 workloads × 9 array configurations) across worker
//! threads, plus the model-serving request loop (`serve` module): compiled
//! program sessions (compile-once/serve-many, `crate::program`) and ad-hoc
//! GEMM requests over the PJRT runtime.

pub mod admission;
pub mod fleet;
pub mod sched;
pub mod serve;

use crate::arch::config::ArchConfig;
use crate::baselines;
use crate::mapper::search::{estimate, MapperOptions};
use crate::mapper::{search, Decision};
use crate::perf::PerfReport;
use crate::util::geomean;
use crate::workloads::Gemm;

/// One evaluation point: a workload on a configuration, mapped by the
/// FEATHER+ mapper, costed under both instruction regimes.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub workload: Gemm,
    pub config: String,
    pub decision: Decision,
    /// Same mapping, micro-instruction control.
    pub micro: PerfReport,
    pub minisa_bytes: u64,
    pub micro_bytes: u64,
    pub data_bytes: u64,
}

impl EvalRow {
    /// Fig. 10: end-to-end speedup of MINISA over micro-instructions.
    pub fn speedup(&self) -> f64 {
        self.micro.total_cycles / self.decision.report.total_cycles.max(1.0)
    }
    /// Fig. 12: off-chip instruction-byte reduction.
    pub fn instr_reduction(&self) -> f64 {
        self.micro_bytes as f64 / self.minisa_bytes.max(1) as f64
    }
    /// Fig. 12 lines: instruction-to-data byte ratios.
    pub fn micro_instr_to_data(&self) -> f64 {
        self.micro_bytes as f64 / self.data_bytes.max(1) as f64
    }
    pub fn minisa_instr_to_data(&self) -> f64 {
        self.minisa_bytes as f64 / self.data_bytes.max(1) as f64
    }
}

/// Evaluate one (workload, config) point.
pub fn evaluate_one(cfg: &ArchConfig, g: &Gemm, opts: &MapperOptions) -> Option<EvalRow> {
    let decision = search(cfg, g, opts)?;
    let micro =
        estimate(cfg, g, &decision.choice, decision.i_order, decision.o_order, false)?;
    let (minisa_bits, micro_bits) =
        crate::mapper::search::instr_traffic(cfg, g, &decision.choice)?;
    Some(EvalRow {
        workload: g.clone(),
        config: cfg.name(),
        decision,
        micro,
        minisa_bytes: minisa_bits.div_ceil(8),
        micro_bytes: micro_bits.div_ceil(8),
        data_bytes: g.data_bytes(cfg.elem_bytes, cfg.acc_bytes),
    })
}

/// Evaluate a workload suite across configurations on `threads` workers
/// (the artifact's `--jobs` knob).
pub fn evaluate_suite(
    cfgs: &[ArchConfig],
    workloads: &[Gemm],
    opts: &MapperOptions,
    threads: usize,
) -> Vec<EvalRow> {
    let points: Vec<(ArchConfig, Gemm)> = cfgs
        .iter()
        .flat_map(|c| workloads.iter().map(move |w| (c.clone(), w.clone())))
        .collect();
    let threads = threads.max(1).min(points.len().max(1));
    let chunk = crate::util::ceil_div(points.len().max(1), threads);
    let inner = MapperOptions { threads: 1, ..opts.clone() };
    let mut rows: Vec<EvalRow> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for part in points.chunks(chunk.max(1)) {
            let inner = inner.clone();
            handles.push(s.spawn(move || {
                part.iter()
                    .filter_map(|(c, w)| evaluate_one(c, w, &inner))
                    .collect::<Vec<_>>()
            }));
        }
        handles.into_iter().flat_map(|h| h.join().expect("eval worker")).collect()
    });
    rows.sort_by(|a, b| (a.config.clone(), a.workload.name.clone())
        .cmp(&(b.config.clone(), b.workload.name.clone())));
    rows
}

/// Geometric-mean summary of a set of rows (per config).
#[derive(Debug, Clone)]
pub struct ConfigSummary {
    pub config: String,
    pub geo_speedup: f64,
    pub geo_instr_reduction: f64,
    pub mean_stall_micro: f64,
    pub mean_stall_minisa: f64,
    pub mean_utilization: f64,
}

pub fn summarize_by_config(rows: &[EvalRow]) -> Vec<ConfigSummary> {
    let mut configs: Vec<String> = rows.iter().map(|r| r.config.clone()).collect();
    configs.sort();
    configs.dedup();
    configs
        .into_iter()
        .map(|c| {
            let rs: Vec<&EvalRow> = rows.iter().filter(|r| r.config == c).collect();
            let sp: Vec<f64> = rs.iter().map(|r| r.speedup()).collect();
            let ir: Vec<f64> = rs.iter().map(|r| r.instr_reduction()).collect();
            let stall_mi: Vec<f64> =
                rs.iter().map(|r| r.micro.instr_stall_fraction()).collect();
            let stall_mn: Vec<f64> =
                rs.iter().map(|r| r.decision.report.instr_stall_fraction()).collect();
            let util: Vec<f64> = rs.iter().map(|r| r.decision.report.utilization()).collect();
            ConfigSummary {
                config: c,
                geo_speedup: geomean(&sp),
                geo_instr_reduction: geomean(&ir),
                mean_stall_micro: crate::util::mean(&stall_mi),
                mean_stall_minisa: crate::util::mean(&stall_mn),
                mean_utilization: crate::util::mean(&util),
            }
        })
        .collect()
}

/// Fig. 11 comparison row: FEATHER+ (64× 16×256 mesh) vs GPU vs TPU.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub workload: Gemm,
    pub feather_us: f64,
    pub gpu_us: f64,
    pub tpu_us: f64,
    pub feather_utilization: f64,
}

/// Run the Fig. 11 comparison for a workload set.
pub fn compare_devices(workloads: &[Gemm], opts: &MapperOptions, threads: usize) -> Vec<CompareRow> {
    let cfg = ArchConfig::paper(16, 256);
    let rows = evaluate_suite(&[cfg.clone()], workloads, opts, threads);
    rows.into_iter()
        .map(|r| {
            let single = r.decision.report.latency_us(&cfg);
            CompareRow {
                feather_us: baselines::featherplus_mesh_latency_us(single, r.workload.m, 64),
                gpu_us: baselines::gpu_latency_us(&r.workload),
                tpu_us: baselines::tpu_latency_us(&r.workload),
                feather_utilization: r.decision.report.utilization(),
                workload: r.workload,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> MapperOptions {
        MapperOptions { full_layout_search: false, ..Default::default() }
    }

    #[test]
    fn evaluate_one_point() {
        let cfg = ArchConfig::paper(4, 16);
        let g = Gemm::new("t", "test", 1024, 40, 88);
        let row = evaluate_one(&cfg, &g, &opts()).unwrap();
        assert!(row.speedup() >= 1.0 || row.speedup() > 0.5); // sane
        assert!(row.instr_reduction() > 10.0);
        assert!(row.minisa_bytes < row.micro_bytes);
    }

    #[test]
    fn suite_eval_parallel_deterministic() {
        let cfgs = vec![ArchConfig::paper(4, 4), ArchConfig::paper(4, 16)];
        let ws = crate::workloads::suite_small()[..3].to_vec();
        let a = evaluate_suite(&cfgs, &ws, &opts(), 1);
        let b = evaluate_suite(&cfgs, &ws, &opts(), 8);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.workload.name, y.workload.name);
            assert_eq!(x.config, y.config);
            assert_eq!(x.minisa_bytes, y.minisa_bytes);
        }
    }

    #[test]
    fn summaries_cover_all_configs() {
        let cfgs = vec![ArchConfig::paper(4, 4), ArchConfig::paper(8, 8)];
        let ws = vec![Gemm::new("a", "t", 512, 40, 88), Gemm::new("b", "t", 512, 64, 64)];
        let rows = evaluate_suite(&cfgs, &ws, &opts(), 4);
        let sums = summarize_by_config(&rows);
        assert_eq!(sums.len(), 2);
        for s in sums {
            assert!(s.geo_instr_reduction > 1.0, "{}: {}", s.config, s.geo_instr_reduction);
        }
    }

    #[test]
    fn speedup_grows_with_array_scale() {
        // Fig. 10's headline: geomean speedup increases with scale.
        let ws = vec![Gemm::new("t1", "t", 8192, 40, 88)];
        let small = evaluate_suite(&[ArchConfig::paper(4, 4)], &ws, &opts(), 1);
        let large = evaluate_suite(&[ArchConfig::paper(16, 256)], &ws, &opts(), 1);
        assert!(large[0].speedup() > small[0].speedup());
        assert!(large[0].speedup() > 5.0, "16x256 speedup {}", large[0].speedup());
    }

    #[test]
    fn device_compare_shapes() {
        let ws = vec![
            Gemm::new("irr", "FHE-BConv", 65536, 40, 88),
            Gemm::new("reg", "FHE-NTT", 256, 2048, 2048),
        ];
        let rows = compare_devices(&ws, &opts(), 2);
        assert_eq!(rows.len(), 2);
        let irr = &rows[0];
        // Irregular shape: FEATHER+ beats the TPU (padding-bound).
        assert!(
            irr.feather_us < irr.tpu_us,
            "feather {} vs tpu {}",
            irr.feather_us,
            irr.tpu_us
        );
        assert!(irr.feather_utilization > 0.3);
    }
}
