//! Admission control for the serving front door: per-request deadlines and
//! QoS classes, a per-session token-bucket rate limiter, and a global
//! in-flight budget with graduated load shedding.
//!
//! The policy (docs/SERVING.md) is deliberately small:
//!
//! * Every request carries an [`Admission`] tag — a [`QosClass`] plus an
//!   optional absolute deadline. Requests whose deadline has passed are
//!   answered with a typed `deadline_exceeded` error at the next hand-off
//!   point instead of occupying a device.
//! * A token bucket per session (keyed by the leader's batch-affinity hash)
//!   bounds the sustained rate of `Batch`/`BestEffort` traffic.
//!   `Interactive` traffic is exempt from the rate limiter and only sheds
//!   at the hard capacity wall.
//! * A global in-flight budget sheds `BestEffort` first (at half budget),
//!   then `Batch` (at full budget), then `Interactive` (at twice budget —
//!   the hard wall that keeps the leader from queueing without bound).
//!
//! All limits default to "off" (`rate_per_s = ∞`, `max_in_flight = MAX`)
//! so a server constructed with `ServerOptions::default()` behaves exactly
//! like the pre-admission front door.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Quality-of-service class carried by every request. Ordering is strict:
/// under pressure `BestEffort` sheds before `Batch`, and `Batch` before
/// `Interactive`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum QosClass {
    /// Latency-sensitive traffic: exempt from the rate limiter, shed only
    /// at the hard capacity wall.
    Interactive,
    /// Throughput traffic: rate-limited, shed at the full in-flight budget.
    Batch,
    /// Scavenger traffic: rate-limited, shed first (at half budget).
    BestEffort,
}

impl QosClass {
    pub const ALL: [QosClass; 3] = [QosClass::Interactive, QosClass::Batch, QosClass::BestEffort];

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
            QosClass::BestEffort => "best-effort",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "interactive" => Ok(QosClass::Interactive),
            "batch" => Ok(QosClass::Batch),
            "best-effort" | "besteffort" => Ok(QosClass::BestEffort),
            other => Err(format!(
                "unknown QoS class '{other}' (expected interactive, batch, best-effort)"
            )),
        }
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Per-request admission tag: QoS class plus optional absolute deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    pub qos: QosClass,
    /// Absolute wall-clock deadline; `None` = no deadline.
    pub deadline: Option<Instant>,
}

impl Default for Admission {
    fn default() -> Self {
        Admission { qos: QosClass::Interactive, deadline: None }
    }
}

impl Admission {
    pub fn new(qos: QosClass) -> Self {
        Admission { qos, deadline: None }
    }

    /// Set a deadline `ms` milliseconds from now.
    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline = Some(Instant::now() + Duration::from_millis(ms));
        self
    }

    /// `true` iff the deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Machine-readable error codes on [`super::serve::Response`]. The string
/// forms are stable (docs/SERVING.md §Error codes) — clients switch on
/// these, not on the human-readable message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Rejected by admission control (rate limit or in-flight budget).
    Shed,
    /// Deadline passed before the result could be produced.
    DeadlineExceeded,
    /// The session was unregistered while the request was in flight.
    SessionGone,
    /// A shard exceeded the per-shard watchdog and the retry budget ran out.
    Watchdog,
    /// Every device whose arch fingerprint matches the session has dropped
    /// out of a heterogeneous fleet — the work cannot be placed anywhere
    /// (arch-incompatible survivors are never used).
    NoEligibleDevice,
    /// Execution failed (validation error, executor error, device panic...).
    Exec,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::Shed => "shed",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::SessionGone => "session_gone",
            ErrorCode::Watchdog => "watchdog",
            ErrorCode::NoEligibleDevice => "no_eligible_device",
            ErrorCode::Exec => "exec",
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.as_str())
    }
}

/// Admission policy knobs. Defaults disable every limit, preserving the
/// behavior of a front door without admission control.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionOptions {
    /// Sustained per-session token refill rate (requests/second) for
    /// `Batch`/`BestEffort` traffic. `f64::INFINITY` = unlimited.
    pub rate_per_s: f64,
    /// Token-bucket capacity (burst size), in requests.
    pub burst: f64,
    /// Global in-flight budget: `Batch` sheds at this many admitted
    /// requests outstanding, `BestEffort` at half, `Interactive` at twice.
    /// `usize::MAX` = unlimited.
    pub max_in_flight: usize,
}

impl Default for AdmissionOptions {
    fn default() -> Self {
        AdmissionOptions { rate_per_s: f64::INFINITY, burst: 16.0, max_in_flight: usize::MAX }
    }
}

/// Admission verdict for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Admitted; the caller must balance with [`AdmissionController::complete`]
    /// exactly once when the request is answered.
    Admit,
    /// Shed by the rate limiter or the in-flight budget.
    Shed,
    /// Dead on arrival: the deadline already passed.
    Expired,
}

#[derive(Debug)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn try_take(&mut self, now: Instant, rate: f64, burst: f64) -> bool {
        if rate.is_infinite() {
            return true;
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * rate).min(burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// The front-door gate: one per [`super::serve::Server`]. Tracks per-session
/// token buckets and the global count of admitted-but-unanswered requests.
#[derive(Debug)]
pub struct AdmissionController {
    opts: AdmissionOptions,
    buckets: Mutex<HashMap<u64, TokenBucket>>,
    in_flight: AtomicUsize,
}

impl AdmissionController {
    pub fn new(opts: AdmissionOptions) -> Self {
        AdmissionController { opts, buckets: Mutex::new(HashMap::new()), in_flight: AtomicUsize::new(0) }
    }

    /// Admitted-but-unanswered request count.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Shedding threshold for `qos` given the configured budget.
    fn capacity_for(&self, qos: QosClass) -> usize {
        let max = self.opts.max_in_flight;
        if max == usize::MAX {
            return usize::MAX;
        }
        match qos {
            QosClass::BestEffort => max.div_ceil(2),
            QosClass::Batch => max,
            QosClass::Interactive => max.saturating_mul(2),
        }
    }

    /// Gate one request for the session identified by `session_key` (the
    /// leader's batch-affinity hash). On [`Verdict::Admit`] the in-flight
    /// count is incremented; the caller must call [`Self::complete`] once
    /// per admitted request when its response is sent.
    pub fn admit(&self, session_key: u64, adm: &Admission, now: Instant) -> Verdict {
        if adm.expired(now) {
            return Verdict::Expired;
        }
        // Hard capacity wall first: it applies to every class.
        if self.in_flight.load(Ordering::Relaxed) >= self.capacity_for(adm.qos) {
            return Verdict::Shed;
        }
        // Rate limiter: Interactive is exempt by policy.
        if adm.qos != QosClass::Interactive {
            let mut buckets = self.buckets.lock().unwrap_or_else(|p| p.into_inner());
            let b = buckets
                .entry(session_key)
                .or_insert_with(|| TokenBucket { tokens: self.opts.burst, last: now });
            if !b.try_take(now, self.opts.rate_per_s, self.opts.burst.max(1.0)) {
                return Verdict::Shed;
            }
        }
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        Verdict::Admit
    }

    /// Balance `n` admitted requests that have now been answered (success
    /// or typed error — every admitted request is answered exactly once).
    pub fn complete(&self, n: usize) {
        let prev = self.in_flight.fetch_sub(n, Ordering::Relaxed);
        debug_assert!(prev >= n, "in-flight underflow: {prev} - {n}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adm(qos: QosClass) -> Admission {
        Admission::new(qos)
    }

    #[test]
    fn defaults_admit_everything() {
        let c = AdmissionController::new(AdmissionOptions::default());
        let now = Instant::now();
        for qos in QosClass::ALL {
            for _ in 0..1000 {
                assert_eq!(c.admit(7, &adm(qos), now), Verdict::Admit);
            }
        }
        assert_eq!(c.in_flight(), 3000);
        c.complete(3000);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn expired_requests_are_dead_on_arrival() {
        let c = AdmissionController::new(AdmissionOptions::default());
        let t0 = Instant::now();
        let past = Admission { qos: QosClass::Interactive, deadline: Some(t0) };
        assert_eq!(c.admit(1, &past, t0 + Duration::from_millis(1)), Verdict::Expired);
        // Not yet expired: admitted.
        let future =
            Admission { qos: QosClass::Interactive, deadline: Some(t0 + Duration::from_secs(60)) };
        assert_eq!(c.admit(1, &future, t0), Verdict::Admit);
        assert_eq!(c.in_flight(), 1);
    }

    #[test]
    fn capacity_sheds_best_effort_then_batch_then_interactive() {
        let c = AdmissionController::new(AdmissionOptions {
            max_in_flight: 2,
            ..Default::default()
        });
        let now = Instant::now();
        // Fill to the BestEffort threshold (ceil(2/2) = 1).
        assert_eq!(c.admit(1, &adm(QosClass::Interactive), now), Verdict::Admit);
        assert_eq!(c.admit(1, &adm(QosClass::BestEffort), now), Verdict::Shed);
        assert_eq!(c.admit(1, &adm(QosClass::Batch), now), Verdict::Admit);
        // At the full budget (2): Batch sheds, Interactive still admitted.
        assert_eq!(c.admit(1, &adm(QosClass::Batch), now), Verdict::Shed);
        assert_eq!(c.admit(1, &adm(QosClass::Interactive), now), Verdict::Admit);
        assert_eq!(c.admit(1, &adm(QosClass::Interactive), now), Verdict::Admit);
        // At the hard wall (2 * 2 = 4): even Interactive sheds.
        assert_eq!(c.in_flight(), 4);
        assert_eq!(c.admit(1, &adm(QosClass::Interactive), now), Verdict::Shed);
        // Draining reopens the gate, lowest class last.
        c.complete(4);
        assert_eq!(c.admit(1, &adm(QosClass::BestEffort), now), Verdict::Admit);
    }

    #[test]
    fn token_bucket_rate_limits_batch_but_not_interactive() {
        let c = AdmissionController::new(AdmissionOptions {
            rate_per_s: 10.0,
            burst: 2.0,
            ..Default::default()
        });
        let t0 = Instant::now();
        // Burst of 2, then the bucket is dry.
        assert_eq!(c.admit(9, &adm(QosClass::Batch), t0), Verdict::Admit);
        assert_eq!(c.admit(9, &adm(QosClass::BestEffort), t0), Verdict::Admit);
        assert_eq!(c.admit(9, &adm(QosClass::Batch), t0), Verdict::Shed);
        // Interactive is exempt from the rate limiter.
        assert_eq!(c.admit(9, &adm(QosClass::Interactive), t0), Verdict::Admit);
        // 100ms refills one token at 10/s.
        let t1 = t0 + Duration::from_millis(100);
        assert_eq!(c.admit(9, &adm(QosClass::Batch), t1), Verdict::Admit);
        assert_eq!(c.admit(9, &adm(QosClass::Batch), t1), Verdict::Shed);
        // Buckets are per-session: a different key has its own burst.
        assert_eq!(c.admit(10, &adm(QosClass::Batch), t1), Verdict::Admit);
    }

    #[test]
    fn error_codes_are_stable_strings() {
        assert_eq!(ErrorCode::Shed.as_str(), "shed");
        assert_eq!(ErrorCode::DeadlineExceeded.as_str(), "deadline_exceeded");
        assert_eq!(ErrorCode::SessionGone.as_str(), "session_gone");
        assert_eq!(ErrorCode::Watchdog.as_str(), "watchdog");
        assert_eq!(ErrorCode::NoEligibleDevice.as_str(), "no_eligible_device");
        assert_eq!(ErrorCode::Exec.as_str(), "exec");
        assert_eq!(QosClass::parse("interactive"), Ok(QosClass::Interactive));
        assert_eq!(QosClass::parse("best-effort"), Ok(QosClass::BestEffort));
        assert!(QosClass::parse("gold").is_err());
    }
}
