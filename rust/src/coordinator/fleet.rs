//! Fleet executor — N simulated FEATHER+ devices serving one request stream.
//!
//! MINISA's compiled artifacts are small enough to re-dispatch freely
//! (§IV-G), which makes a *fleet* of devices the natural scaling axis for
//! the serving stack: one compiled [`Program`] (plans hold addressing, not
//! values) can execute anywhere, so work shards across devices at two
//! granularities:
//!
//! * **Request-parallel** — the batcher's per-key batches are routed onto
//!   devices by key affinity (same program → same device → warm per-device
//!   plan caches and simulators) and drained by per-device worker threads
//!   with work *stealing*: an idle device takes jobs from any backlogged —
//!   or dropped — neighbour, so load imbalance and dropouts self-heal.
//! * **Tile-parallel** — one large batch's activation rows are split into
//!   contiguous shards ([`plan_shards`]), each executed on an idle device
//!   against the same compiled program ([`Program::shard_rows`]), and the
//!   shard outputs are stitched back in `OutputBuffer` row order. Rows of a
//!   GEMM chain are independent, so sharded execution is bit-identical to
//!   the single-device path for every [`crate::arith::Element`] backend
//!   (`tests/fleet_conformance.rs` locks this down).
//!
//! Each [`Device`] owns its executor handle and a **persistent per-backend
//! functional simulator** — the device's own plan cache. Executing a
//! compiled program seeds the simulator from the program's precompiled plan
//! set, so steady-state fleet serving performs zero runtime plan compiles
//! (`FleetReport::plan_compiles` stays 0).
//!
//! Failure injection: [`Fleet::fail_device`] drops a device mid-stream. Its
//! queue is drained by surviving workers (counted as requeues), shards
//! assigned to it re-execute on survivors, and new work routes around it.
//! Executor *panics* are contained per shard (the busy slot is restored by
//! a drop guard, never leaked) and surface as error responses — a panic is
//! a bad-operand class problem, not a dropout, so it is not retried.
//!
//! Graceful degradation (docs/SERVING.md): shards carry a cooperative
//! watchdog ([`FleetOptions::shard_timeout_ms`]) — a shard that runs past
//! its budget has its device marked *transiently* failed and is retried on
//! another device with exponential backoff, at most
//! [`FleetOptions::retry_budget`] executions before a typed `watchdog:`
//! error. Transient failures heal: a health probe
//! ([`FleetOptions::probe_after_ms`]) re-admits the device, so a slow blip
//! does not permanently shrink the fleet (permanent [`Fleet::fail_device`]
//! dropouts never rejoin). A deterministic [`FaultPlan`] (compiled under
//! `#[cfg(any(test, feature = "faults"))]`) scripts dropouts, slow shards
//! and executor panics off a seeded RNG so all of this is testable.

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::arch::config::ArchConfig;
use crate::arith::ElemType;
use crate::functional::BlockSim;
use crate::perf::{DeviceLoad, FleetReport, StallModel};
use crate::program::Program;
use crate::with_element;

use super::serve::{execute_program_words_blocked, TileExecutor, WordWeights};

/// Fleet sizing knobs (a subset of `serve::ServerOptions`).
#[derive(Debug, Clone, Copy)]
pub struct FleetOptions {
    /// Number of simulated devices (≥ 1).
    pub devices: usize,
    /// Minimum activation rows per tile-parallel shard: batches smaller
    /// than `2 × shard_min_rows` never split. 1 allows single-row shards.
    pub shard_min_rows: usize,
    /// Per-shard watchdog budget in milliseconds; a shard exceeding it has
    /// its device marked transiently failed and is retried elsewhere.
    /// 0 disables the watchdog.
    pub shard_timeout_ms: u64,
    /// Maximum shard executions (first try + retries) before a typed
    /// `watchdog:` error is returned instead of retrying forever.
    pub retry_budget: usize,
    /// How long a transiently-failed device stays out before a health
    /// probe re-admits it.
    pub probe_after_ms: u64,
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self {
            devices: 1,
            shard_min_rows: 8,
            shard_timeout_ms: 0,
            retry_budget: 3,
            probe_after_ms: 25,
        }
    }
}

/// Per-device execution counters (see [`DeviceLoad`] for field meanings —
/// this is the mutable accumulator behind that report row).
#[derive(Debug, Clone, Default)]
pub struct DeviceStats {
    pub dispatches: u64,
    pub shards: u64,
    pub rows: u64,
    pub busy_us: f64,
    pub steals: u64,
    pub requeues: u64,
    /// Queue time of stolen jobs (submit → steal), the steal-latency column.
    pub steal_wait_us: f64,
    /// Shard executions beyond the first attempt (watchdog requeues).
    pub retries: u64,
    /// Shards that ran past the watchdog budget on this device.
    pub watchdog_trips: u64,
    /// Health-probe re-admissions after a transient failure.
    pub recoveries: u64,
    /// NEST waves issued by this device's functional simulators (word
    /// serving path; executor-backend paths don't expose wave counts).
    pub waves: u64,
    /// Live stall accounting: each executed shard charges its row share of
    /// the program's modeled MINISA and micro-baseline cycles
    /// ([`crate::program::Program::stall`]). Raw GEMM dispatches carry no
    /// perf decision and contribute nothing.
    pub modeled: StallModel,
    /// Cycles the cost-aware scheduler predicted for the work this device
    /// actually executed (`sched::predict_cycles` per executed shard) —
    /// the "predicted" side of the predicted-vs-simulated error that
    /// `DeviceLoad::predict_err` reports. Raw GEMMs contribute nothing.
    pub predicted_cycles: f64,
}

/// A queued unit of fleet work: one batch's dispatch, bound to whichever
/// device's worker executes it.
pub type FleetJob = Box<dyn FnOnce(&Arc<Device>) + Send + 'static>;

/// A [`FleetJob`] plus its enqueue timestamp (steal-latency accounting)
/// and its placement constraints (cost-aware scheduling).
struct QueuedJob {
    job: FleetJob,
    enqueued: Instant,
    /// Arch fingerprint this job's session was compiled for: only devices
    /// with a matching fingerprint may execute it. `None` = unconstrained
    /// (ad-hoc GEMM work runs anywhere).
    fingerprint: Option<u64>,
    /// Scheduler-predicted cycles, charged to the queued device's pending
    /// load at submit and discharged when the job leaves its queue.
    cost: u64,
}

/// One scripted dropout in a [`FaultPlan`]: after the fleet has started
/// `after_shards` shard executions, mark `device` failed (transiently or
/// permanently).
#[cfg(any(test, feature = "faults"))]
#[derive(Debug, Clone)]
pub struct FaultDropout {
    pub device: usize,
    pub after_shards: u64,
    pub transient: bool,
}

/// Deterministic fault-injection schedule, keyed off a seeded RNG plus a
/// global shard counter. Installed with [`Fleet::set_fault_plan`]; every
/// shard execution passes through [`Fleet::fault_point`], which applies
/// scripted dropouts at their shard index and draws slow-shard delays and
/// executor panics from the seeded stream. Compiled only under
/// `#[cfg(any(test, feature = "faults"))]` — production builds carry a
/// no-op stub at the call site.
#[cfg(any(test, feature = "faults"))]
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub dropouts: Vec<FaultDropout>,
    /// Probability a shard sleeps `slow_ms` before executing.
    pub slow_prob: f64,
    pub slow_ms: u64,
    /// Probability a shard's executor panics (contained and answered as a
    /// typed error by the shard runner).
    pub panic_prob: f64,
}

#[cfg(any(test, feature = "faults"))]
struct FaultState {
    plan: FaultPlan,
    rng: crate::util::Lcg,
    shards_started: u64,
}

/// Lock a mutex, clearing poison: fleet bookkeeping must survive executor
/// panics (the panic itself is contained and answered as an error response;
/// wedging a stats or queue lock forever would turn it into a hang).
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// One simulated FEATHER+ device: an executor handle, a persistent
/// per-backend functional simulator (the device's plan cache), a work
/// queue, and liveness/availability flags.
pub struct Device {
    pub id: usize,
    cfg: ArchConfig,
    /// Arch fingerprint of `cfg` (`artifact::arch_fingerprint`), cached:
    /// placement eligibility compares this on every routing decision.
    fingerprint: u64,
    executor: Arc<dyn TileExecutor>,
    /// Currently executing (advisory: used by tile-parallel claiming to
    /// prefer idle devices; correctness never depends on it).
    busy: AtomicBool,
    /// Dropped out (failure injection). Failed devices execute nothing;
    /// their queued work is stolen by survivors.
    failed: AtomicBool,
    /// Failure mode: transient failures are re-admitted by the health
    /// probe after `probe_after_ms`; permanent ones never rejoin.
    transient: AtomicBool,
    /// When the failure was recorded (drives the probe timer).
    failed_at: Mutex<Option<Instant>>,
    stats: Mutex<DeviceStats>,
    /// Runtime wave-plan compiles across this device's simulators — stays 0
    /// when every executed program was compiled ahead of time.
    plan_compiles: AtomicU64,
    /// Persistent per-element-type simulators. Reusing a simulator across
    /// dispatches keeps its seeded plan set resident, which is exactly what
    /// "each device owns its plan cache" means here.
    sims: Mutex<HashMap<ElemType, Box<dyn Any + Send>>>,
    queue: Mutex<VecDeque<QueuedJob>>,
    /// Predicted cycles of work queued on (or claimed from) this device —
    /// the completion-time signal cost-aware placement reads. Charged at
    /// submit, discharged when a job leaves the queue; advisory only,
    /// correctness never depends on it.
    pending: AtomicU64,
}

impl Device {
    fn new(id: usize, cfg: &ArchConfig, executor: Arc<dyn TileExecutor>) -> Self {
        Self {
            id,
            cfg: cfg.clone(),
            fingerprint: crate::artifact::arch_fingerprint(cfg),
            executor,
            busy: AtomicBool::new(false),
            failed: AtomicBool::new(false),
            transient: AtomicBool::new(false),
            failed_at: Mutex::new(None),
            stats: Mutex::new(DeviceStats::default()),
            plan_compiles: AtomicU64::new(0),
            sims: Mutex::new(HashMap::new()),
            queue: Mutex::new(VecDeque::new()),
            pending: AtomicU64::new(0),
        }
    }

    /// This device's architecture (fleets may be heterogeneous).
    pub fn arch(&self) -> &ArchConfig {
        &self.cfg
    }

    /// Arch fingerprint of this device's configuration — the placement
    /// eligibility key (a session may only execute on devices whose
    /// fingerprint matches its program's).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Whether work compiled for `fingerprint` may execute here. `None` is
    /// unconstrained work (ad-hoc GEMMs).
    pub fn eligible(&self, fingerprint: Option<u64>) -> bool {
        !fingerprint.is_some_and(|f| f != self.fingerprint)
    }

    /// Predicted cycles of work currently queued on this device.
    pub fn pending_cycles(&self) -> u64 {
        self.pending.load(Ordering::Acquire)
    }

    fn charge_pending(&self, cycles: u64) {
        self.pending.fetch_add(cycles, Ordering::AcqRel);
    }

    fn discharge_pending(&self, cycles: u64) {
        // Saturating: a shutdown drain or an inline fallback may discharge
        // a job whose charge went to a different (since-reset) counter.
        let _ = self.pending.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
            Some(v.saturating_sub(cycles))
        });
    }

    pub fn is_busy(&self) -> bool {
        self.busy.load(Ordering::Acquire)
    }

    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    /// Record a failure. A permanent failure overrides a transient one;
    /// a transient mark never downgrades an existing permanent failure.
    fn mark_failed(&self, transient: bool) {
        let mut at = lock_clean(&self.failed_at);
        if self.failed.load(Ordering::Acquire) && !self.transient.load(Ordering::Acquire) {
            return; // already permanently failed
        }
        self.transient.store(transient, Ordering::Release);
        self.failed.store(true, Ordering::Release);
        *at = Some(Instant::now());
    }

    /// Health probe: re-admit a transiently-failed device once it has been
    /// out for at least `probe_after`. The probe itself is trivial for a
    /// simulated device (its executor handle is always reachable); the
    /// timer models the quarantine window a real fleet would use.
    fn maybe_recover(&self, probe_after: Duration) -> bool {
        if !self.failed.load(Ordering::Acquire) || !self.transient.load(Ordering::Acquire) {
            return false;
        }
        let mut at = lock_clean(&self.failed_at);
        match *at {
            Some(t0) if t0.elapsed() >= probe_after => {
                *at = None;
                self.transient.store(false, Ordering::Release);
                self.failed.store(false, Ordering::Release);
                lock_clean(&self.stats).recoveries += 1;
                true
            }
            _ => false,
        }
    }

    /// The execution backend this device fronts.
    pub fn executor(&self) -> &Arc<dyn TileExecutor> {
        &self.executor
    }

    /// Snapshot of this device's counters.
    pub fn stats(&self) -> DeviceStats {
        lock_clean(&self.stats).clone()
    }

    /// Runtime plan compiles accumulated by this device's simulators.
    pub fn plan_compiles(&self) -> u64 {
        self.plan_compiles.load(Ordering::Relaxed)
    }

    /// Execute a compiled program on an element-typed activation using this
    /// device's persistent block simulator. The chunked-execution semantics
    /// are [`execute_program_words_blocked`] — the same loop the
    /// throwaway-sim path uses, so the two can never drift apart; this
    /// method only supplies the per-device simulator (whose lanes keep
    /// their seeded plan caches warm across requests) and accounts its plan
    /// compiles.
    pub fn run_program_words(
        &self,
        program: &Program,
        rows: usize,
        input: &[u64],
        weights: &WordWeights,
    ) -> anyhow::Result<Vec<u64>> {
        anyhow::ensure!(
            self.cfg == program.cfg,
            "program compiled for {}, device is {}",
            program.cfg.name(),
            self.cfg.name()
        );
        with_element!(weights.elem(), E => {
            let w: &[Vec<E>] = weights
                .decoded::<E>()
                .ok_or_else(|| anyhow::anyhow!("WordWeights decoded form does not match its tag"))?;
            // Poison from an earlier contained panic is cleared: every
            // execution starts by reloading operands via Load instructions,
            // so interrupted state cannot leak into results.
            let mut sims = lock_clean(&self.sims);
            let block: &mut BlockSim<E> = sims
                .entry(weights.elem())
                .or_insert_with(|| Box::new(BlockSim::<E>::new(&self.cfg)) as Box<dyn Any + Send>)
                .downcast_mut::<BlockSim<E>>()
                .ok_or_else(|| anyhow::anyhow!("device simulator type confusion"))?;
            let compiles_before = block.plan_compiles();
            let waves_before = block.waves();
            let out = execute_program_words_blocked(block, program, rows, input, w);
            let delta = block.plan_compiles() - compiles_before;
            let waves_delta = block.waves() - waves_before;
            drop(sims);
            if delta > 0 {
                self.plan_compiles.fetch_add(delta, Ordering::Relaxed);
            }
            if out.is_ok() {
                let mut st = lock_clean(&self.stats);
                st.waves += waves_delta;
                drop(st);
                self.note_modeled(program, rows);
            }
            out
        })
    }

    /// Live stall accounting: a shard that executed `rows` of `program`
    /// charges that row share of the program's modeled MINISA and
    /// micro-baseline cycles to this device ([`StallModel::absorb_scaled`]),
    /// and the scheduler's prediction for the same shard
    /// (`sched::predict_cycles`) — the two sides of the per-device
    /// predicted-vs-simulated error. Called on successful executions only —
    /// failed or panicked shards completed no modeled work.
    pub(crate) fn note_modeled(&self, program: &Program, rows: usize) {
        let frac = rows as f64 / program.rows().max(1) as f64;
        let mut st = lock_clean(&self.stats);
        st.modeled.absorb_scaled(&program.stall, frac);
        st.predicted_cycles += super::sched::predict_cycles(program, rows);
    }
}

/// Claimed-device handle: releases the busy slot on drop (also on panic —
/// a leaked "busy" device would silently shrink the fleet forever).
struct Lease {
    dev: Arc<Device>,
    owned: bool,
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.owned {
            self.dev.busy.store(false, Ordering::Release);
        }
    }
}

/// Split `rows` activation rows into at most `max_shards` contiguous,
/// near-equal shards of at least `min_rows` rows each (the whole range as
/// one shard when `rows < 2·min_rows`). Always covers `0..rows` exactly, in
/// order — the stitching invariant.
pub fn plan_shards(rows: usize, max_shards: usize, min_rows: usize) -> Vec<Range<usize>> {
    if rows == 0 {
        return Vec::new();
    }
    let min_rows = min_rows.max(1);
    let n = (rows / min_rows).clamp(1, max_shards.max(1));
    let base = rows / n;
    let extra = rows % n;
    let mut v = Vec::with_capacity(n);
    let mut r0 = 0usize;
    for i in 0..n {
        let len = base + usize::from(i < extra);
        v.push(r0..r0 + len);
        r0 += len;
    }
    debug_assert_eq!(r0, rows);
    v
}

/// The fleet: N devices, their work queues and worker threads, and the
/// tile-parallel sharding executor. Shared as `Arc<Fleet>` by the serving
/// coordinator; usable standalone (`cli::cmd_run --devices N`).
pub struct Fleet {
    pub cfg: ArchConfig,
    opts: FleetOptions,
    devices: Vec<Arc<Device>>,
    /// Event sequence counter for parked-worker wakeup (paired with
    /// `wake`): every producer-side event (submit, dropout, shutdown)
    /// bumps it under the lock, so workers can wait without a timeout and
    /// still never miss a wakeup (see [`Fleet::wait_for_event`]).
    idle: Mutex<u64>,
    wake: Condvar,
    shutdown: AtomicBool,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Scripted fault injection (tests and the `faults` feature only).
    #[cfg(any(test, feature = "faults"))]
    faults: Mutex<Option<FaultState>>,
}

impl Fleet {
    pub fn new(cfg: &ArchConfig, executor: Arc<dyn TileExecutor>, opts: FleetOptions) -> Self {
        let n = opts.devices.max(1);
        Self::with_archs(&vec![cfg.clone(); n], executor, opts)
    }

    /// A heterogeneous fleet: one device per entry of `archs`, each with
    /// its own `ArchConfig` (`ServerOptions::device_archs` /
    /// `--device-archs`). `opts.devices` is ignored — the arch list *is*
    /// the device list. Device 0's arch doubles as the fleet's default
    /// `cfg` (ad-hoc GEMM mapping, legacy single-arch callers).
    pub fn with_archs(
        archs: &[ArchConfig],
        executor: Arc<dyn TileExecutor>,
        opts: FleetOptions,
    ) -> Self {
        assert!(!archs.is_empty(), "fleet needs at least one device arch");
        let devices = archs
            .iter()
            .enumerate()
            .map(|(id, cfg)| Arc::new(Device::new(id, cfg, Arc::clone(&executor))))
            .collect();
        Self {
            cfg: archs[0].clone(),
            opts,
            devices,
            idle: Mutex::new(0),
            wake: Condvar::new(),
            shutdown: AtomicBool::new(false),
            workers: Mutex::new(Vec::new()),
            #[cfg(any(test, feature = "faults"))]
            faults: Mutex::new(None),
        }
    }

    /// Publish a wakeup event: bump the sequence under the lock, then wake
    /// every parked worker. Callers must make their state change (queue
    /// push, failed flag, shutdown flag) visible *before* calling this.
    fn wake_all(&self) {
        *lock_clean(&self.idle) += 1;
        self.wake.notify_all();
    }

    /// Snapshot the event sequence. Taken *before* scanning the queues:
    /// any event published after the snapshot makes `wait_for_event`
    /// return immediately, so the scan-then-park window cannot lose work.
    fn event_seq(&self) -> u64 {
        *lock_clean(&self.idle)
    }

    /// Park until an event is published after `seen` (or shutdown). No
    /// timeout: the sequence protocol makes missed wakeups impossible, so
    /// the idle path does not spin, and shutdown latency is one notify.
    fn wait_for_event(&self, seen: u64) {
        let mut g = lock_clean(&self.idle);
        while *g == seen && !self.shutdown.load(Ordering::Acquire) {
            g = self.wake.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    pub fn devices(&self) -> &[Arc<Device>] {
        &self.devices
    }

    /// Arch fingerprints of every device, in device order (duplicates
    /// preserved) — the eligibility list registry lookups filter against
    /// (`registry::Registry::find`): a key is servable here iff its arch
    /// fingerprint appears in this list.
    pub fn fingerprints(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.fingerprint()).collect()
    }

    pub fn options(&self) -> FleetOptions {
        self.opts
    }

    /// Drop a device permanently (failure injection). Work queued on it is
    /// stolen by survivors; shards assigned to it requeue; new work routes
    /// around it. Returns false for an unknown id.
    pub fn fail_device(&self, id: usize) -> bool {
        match self.devices.get(id) {
            Some(d) => {
                d.mark_failed(false);
                // Wake everyone: survivors must drain the failed queue.
                self.wake_all();
                true
            }
            None => false,
        }
    }

    /// Drop a device transiently: the health probe re-admits it after
    /// `probe_after_ms`. Returns false for an unknown id.
    pub fn fail_device_transient(&self, id: usize) -> bool {
        match self.devices.get(id) {
            Some(d) => {
                d.mark_failed(true);
                self.wake_all();
                true
            }
            None => false,
        }
    }

    /// Run the health probe over every device, re-admitting transient
    /// failures whose quarantine has elapsed. Called on the routing and
    /// execution paths — recovery needs no dedicated timer thread because
    /// a device only matters again when there is work to route to it.
    pub fn probe_recover(&self) {
        let probe_after = Duration::from_millis(self.opts.probe_after_ms);
        let mut any = false;
        for d in &self.devices {
            any |= d.maybe_recover(probe_after);
        }
        if any {
            self.wake_all();
        }
    }

    /// Runtime wave-plan compiles summed over devices (0 on the
    /// compile-once path).
    pub fn plan_compiles(&self) -> u64 {
        self.devices.iter().map(|d| d.plan_compiles()).sum()
    }

    /// Per-device roll-up over an observation window of `window_us`
    /// wall-clock microseconds.
    pub fn report(&self, window_us: f64) -> FleetReport {
        FleetReport {
            window: window_us,
            shed: 0,
            expired: 0,
            devices: self
                .devices
                .iter()
                .map(|d| {
                    let st = d.stats();
                    DeviceLoad {
                        device: d.id,
                        busy: st.busy_us,
                        stall: (window_us - st.busy_us).max(0.0),
                        dispatches: st.dispatches,
                        shards: st.shards,
                        rows: st.rows,
                        steals: st.steals,
                        requeues: st.requeues,
                        steal_wait_us: st.steal_wait_us,
                        retries: st.retries,
                        watchdog_trips: st.watchdog_trips,
                        recoveries: st.recoveries,
                        plan_compiles: d.plan_compiles(),
                        waves: st.waves,
                        modeled: st.modeled,
                        group: d.fingerprint,
                        arch: d.cfg.name(),
                        predicted_cycles: st.predicted_cycles,
                        failed: d.is_failed(),
                    }
                })
                .collect(),
        }
    }

    // ------------------------------------------------------------------
    // Request-parallel dispatch: queues, workers, stealing.
    // ------------------------------------------------------------------

    /// Whether worker threads are running (fleet dispatch mode). Without
    /// workers the owner executes jobs inline (single-device serving).
    pub fn workers_active(&self) -> bool {
        !lock_clean(&self.workers).is_empty()
    }

    /// Start one worker thread per device. Idempotent; no-op for a
    /// single-device fleet (inline dispatch is strictly cheaper there).
    pub fn start_workers(self: &Arc<Self>) {
        if self.devices.len() <= 1 {
            return;
        }
        let mut ws = lock_clean(&self.workers);
        if !ws.is_empty() {
            return;
        }
        for d in &self.devices {
            let fleet = Arc::clone(self);
            let dev = Arc::clone(d);
            ws.push(
                std::thread::Builder::new()
                    .name(format!("fleet-dev{}", dev.id))
                    .spawn(move || fleet.worker_loop(dev))
                    .expect("spawn fleet worker"),
            );
        }
    }

    /// Enqueue an unconstrained job, routed by `affinity` (a batch-key
    /// hash: same key → same device, keeping that device's simulators and
    /// plan caches warm). See [`Fleet::submit_eligible`].
    pub fn submit(&self, affinity: u64, job: FleetJob) {
        self.submit_eligible(affinity, None, 0, job);
    }

    /// Enqueue a job with placement constraints and a predicted cost.
    ///
    /// Routing considers only surviving devices whose arch fingerprint
    /// matches `fingerprint` (any surviving device when `None`), and picks
    /// the eligible device predicted to finish this job **earliest**: the
    /// one with the least pending predicted cycles (eligible devices share
    /// one arch, so the job itself costs the same everywhere it may run).
    /// Ties rotate by `affinity`, preserving warm-cache routing while the
    /// fleet is idle. If no eligible device survives, the job runs inline
    /// on the caller so its requests still get typed error responses
    /// instead of hanging in a queue nobody drains.
    pub fn submit_eligible(
        &self,
        affinity: u64,
        fingerprint: Option<u64>,
        cost: u64,
        job: FleetJob,
    ) {
        self.probe_recover();
        let eligible: Vec<&Arc<Device>> = self
            .devices
            .iter()
            .filter(|d| !d.is_failed() && d.eligible(fingerprint))
            .collect();
        if eligible.is_empty() {
            let dev = &self.devices[(affinity % self.devices.len() as u64) as usize];
            job(dev);
            return;
        }
        let start = (affinity % eligible.len() as u64) as usize;
        let mut best = start;
        for k in 1..eligible.len() {
            let i = (start + k) % eligible.len();
            if eligible[i].pending_cycles() < eligible[best].pending_cycles() {
                best = i;
            }
        }
        let dev = eligible[best];
        dev.charge_pending(cost);
        lock_clean(&dev.queue).push_back(QueuedJob {
            job,
            enqueued: Instant::now(),
            fingerprint,
            cost,
        });
        self.wake_all();
    }

    /// Pop work for `dev`: own queue first, then steal from any other
    /// device's queue (id order from the right neighbour). A failed device
    /// never takes work, and a steal takes only jobs `dev` is **eligible**
    /// for (matching arch fingerprint) — an incompatible job stays queued
    /// on its victim for an eligible device to drain. The one exception: a
    /// *failed* victim's jobs may be rescued by anyone, because a rescued
    /// job is answered through the execution path (which enforces
    /// eligibility itself and returns a typed `no eligible device` error
    /// when the session's arch has no survivor) — refusing it would strand
    /// its requests forever. Returns the job plus whether it was stolen and
    /// whether the victim had dropped (a requeue).
    fn next_job(&self, dev: &Device) -> Option<(QueuedJob, bool, bool)> {
        if dev.is_failed() {
            return None;
        }
        if let Some(j) = lock_clean(&dev.queue).pop_front() {
            dev.discharge_pending(j.cost);
            return Some((j, false, false));
        }
        let n = self.devices.len();
        for k in 1..n {
            let victim = &self.devices[(dev.id + k) % n];
            let victim_failed = victim.is_failed();
            let mut q = lock_clean(&victim.queue);
            let pos = q
                .iter()
                .position(|j| victim_failed || dev.eligible(j.fingerprint));
            if let Some(p) = pos {
                let j = q.remove(p).expect("position is in range");
                drop(q);
                victim.discharge_pending(j.cost);
                return Some((j, true, victim_failed));
            }
        }
        None
    }

    fn worker_loop(&self, dev: Arc<Device>) {
        loop {
            self.probe_recover();
            // Snapshot the event sequence BEFORE scanning the queues: any
            // submit that lands after the snapshot bumps the sequence, so
            // the untimed wait below returns immediately instead of
            // sleeping on work we failed to observe.
            let seen = self.event_seq();
            if self.run_next_job(&dev) {
                continue;
            }
            if self.shutdown.load(Ordering::Acquire) {
                // Submissions all happen before shutdown is set; one more
                // pass after observing it closes the race where a job lands
                // between our empty-queue check and the flag read.
                if self.run_next_job(&dev) {
                    continue;
                }
                break;
            }
            self.wait_for_event(seen);
        }
    }

    /// Execute one queued job if any is available. The busy slot is held
    /// for the duration and restored by the lease guard even if the job
    /// panics — no leaked busy devices, and a panicking job never kills the
    /// worker (the dispatch protocol inside the job answers its requests
    /// with error responses; this is the backstop).
    fn run_next_job(&self, dev: &Arc<Device>) -> bool {
        let Some((queued, stolen, from_failed)) = self.next_job(dev) else {
            return false;
        };
        let wait_us = queued.enqueued.elapsed().as_secs_f64() * 1e6;
        let job = queued.job;
        dev.busy.store(true, Ordering::Release);
        let _lease = Lease { dev: Arc::clone(dev), owned: true };
        // A panicking job is contained here as a backstop (the dispatch
        // protocol inside the job already answers its requests with error
        // responses before any executor call can panic); the lease restores
        // the busy slot either way.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(dev)));
        let mut st = lock_clean(&dev.stats);
        st.dispatches += 1;
        if stolen {
            st.steals += 1;
            st.steal_wait_us += wait_us;
        }
        if from_failed {
            st.requeues += 1;
        }
        true
    }

    /// Stop workers and join them, then drain any stranded jobs inline
    /// (possible only when every device dropped): each runs to completion
    /// so its requests are answered — with errors from the all-dropped
    /// execution path — rather than leaking.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.wake_all();
        let ws: Vec<_> = lock_clean(&self.workers).drain(..).collect();
        for h in ws {
            let _ = h.join();
        }
        for d in &self.devices {
            // Take the whole backlog in one locked step, then execute with
            // the queue lock released.
            let jobs: Vec<QueuedJob> = lock_clean(&d.queue).drain(..).collect();
            for j in jobs {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (j.job)(d)));
            }
        }
    }

    // ------------------------------------------------------------------
    // Tile-parallel sharded execution.
    // ------------------------------------------------------------------

    /// Claim up to `want` idle surviving devices (never `exclude`), all
    /// eligible for `fingerprint`. Each claim flips the busy slot; the
    /// returned leases restore it on drop.
    fn claim_idle(&self, exclude: usize, want: usize, fingerprint: Option<u64>) -> Vec<Lease> {
        let mut out = Vec::new();
        for d in &self.devices {
            if out.len() >= want {
                break;
            }
            if d.id == exclude || d.is_failed() || !d.eligible(fingerprint) {
                continue;
            }
            if d.busy
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                if d.is_failed() {
                    // Dropped between the liveness check and the claim.
                    d.busy.store(false, Ordering::Release);
                    continue;
                }
                out.push(Lease { dev: Arc::clone(d), owned: true });
            }
        }
        out
    }

    /// Execute one shard with dropout requeue and a bounded retry budget:
    /// the assigned device first, then the other leased devices, then any
    /// surviving device. Executor panics are contained (→ `Err`, busy slots
    /// restored by the leases) and not retried — unlike a dropout, a panic
    /// is deterministic in the operands. Executor `Err`s are likewise final.
    /// A shard that runs past the watchdog budget has its device marked
    /// transiently failed and is retried on the next candidate with
    /// exponential backoff, up to `retry_budget` executions; then a typed
    /// `watchdog:` error. Accounts shard/row/busy stats on the executing
    /// device.
    fn run_one_shard<T, E>(
        &self,
        devs: &[Arc<Device>],
        first: usize,
        range: Range<usize>,
        fingerprint: Option<u64>,
        exec: &E,
    ) -> anyhow::Result<Vec<T>>
    where
        E: Fn(&Device, Range<usize>) -> anyhow::Result<Vec<T>> + Sync,
    {
        let mut candidates: Vec<&Arc<Device>> = Vec::with_capacity(self.devices.len());
        candidates.push(&devs[first]);
        candidates.extend(devs.iter().enumerate().filter(|(i, _)| *i != first).map(|(_, d)| d));
        for d in &self.devices {
            if !candidates.iter().any(|c| c.id == d.id) {
                candidates.push(d);
            }
        }
        let watchdog_us = self.opts.shard_timeout_ms as f64 * 1e3; // 0 = disabled
        let budget = self.opts.retry_budget.max(1);
        let mut attempts = 0usize;
        let mut ineligible = 0usize;
        let mut last_trip: Option<anyhow::Error> = None;
        for (ci, dev) in candidates.into_iter().enumerate() {
            if !dev.eligible(fingerprint) {
                // Wrong arch: this device can never execute this program
                // (its plans encode another config's addressing) — skip it
                // even as a last resort.
                ineligible += 1;
                continue;
            }
            if dev.is_failed() {
                continue;
            }
            if attempts >= budget {
                break;
            }
            if attempts > 0 {
                // Exponential backoff between retries, capped at 8ms — long
                // enough to let a transient blip pass, short enough to stay
                // well inside interactive deadlines.
                std::thread::sleep(Duration::from_millis(1u64 << (attempts - 1).min(3)));
            }
            attempts += 1;
            let requeued = ci > 0;
            let t0 = Instant::now();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.fault_point(dev);
                exec(dev, range.clone())
            }));
            let busy = t0.elapsed().as_secs_f64() * 1e6;
            let tripped = watchdog_us > 0.0 && busy > watchdog_us;
            let mut st = lock_clean(&dev.stats);
            st.shards += 1;
            st.rows += range.len() as u64;
            st.busy_us += busy;
            if requeued {
                st.requeues += 1;
            }
            if attempts > 1 {
                st.retries += 1;
            }
            if tripped {
                st.watchdog_trips += 1;
            }
            drop(st);
            match r {
                Err(_) => {
                    return Err(anyhow::anyhow!(
                        "device {} executor panicked on rows {}..{}",
                        dev.id,
                        range.start,
                        range.end
                    ))
                }
                Ok(Err(e)) => return Err(e), // deterministic executor error: final
                Ok(Ok(res)) => {
                    if !tripped {
                        return Ok(res);
                    }
                    // Watchdog trip: the shard completed (the simulated
                    // executors are cooperative) but far over budget — a
                    // real fleet would have abandoned it. Quarantine the
                    // device and requeue on a survivor; with a single
                    // device there is nowhere better, so keep it serving.
                    if self.devices.len() > 1 {
                        dev.mark_failed(true);
                        self.wake_all();
                    } else {
                        return Ok(res);
                    }
                    last_trip = Some(anyhow::anyhow!(
                        "watchdog: device {} exceeded {}ms budget on rows {}..{} ({:.1}ms)",
                        dev.id,
                        self.opts.shard_timeout_ms,
                        range.start,
                        range.end,
                        busy / 1e3
                    ));
                }
            }
        }
        if let Some(e) = last_trip {
            return Err(anyhow::anyhow!(
                "watchdog: retry budget exhausted after {attempts} attempt(s) for rows {}..{}: {e}",
                range.start,
                range.end
            ));
        }
        if let Some(fp) = fingerprint {
            if ineligible > 0 {
                // Some devices were skipped for arch mismatch, and every
                // arch-compatible one has dropped: a typed placement error,
                // never a silent wrong-arch execution.
                return Err(anyhow::anyhow!(
                    "no eligible device for rows {}..{}: every device matching arch fingerprint {:016x} has dropped ({} arch-incompatible device(s) skipped)",
                    range.start,
                    range.end,
                    fp,
                    ineligible
                ));
            }
        }
        Err(anyhow::anyhow!(
            "no surviving device for rows {}..{} (all {} devices dropped)",
            range.start,
            range.end,
            self.devices.len()
        ))
    }

    /// Row-sharded execution: split `rows` output rows into contiguous
    /// shards over the home device plus currently-idle devices, execute
    /// each shard (`exec(device, row_range)` → that range's output,
    /// `range.len() × out_width` items), and stitch the outputs back in row
    /// order. With one usable device (or too few rows to split) this is a
    /// plain call on that device — the single-device path and the sharded
    /// path are the same code. Unconstrained, evenly-split variant of
    /// [`Fleet::exec_row_sharded_weighted`] (ad-hoc GEMMs, no cost model).
    pub fn exec_row_sharded<T, E>(
        &self,
        home: Option<&Arc<Device>>,
        rows: usize,
        out_width: usize,
        exec: E,
    ) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        E: Fn(&Device, Range<usize>) -> anyhow::Result<Vec<T>> + Sync,
    {
        self.exec_row_sharded_weighted(home, rows, out_width, None, exec)
    }

    /// Row-sharded execution with placement constraints and cost-weighted
    /// row splits. `cost` carries the session's arch fingerprint (only
    /// matching devices may execute shards — ineligible devices are never
    /// claimed and never scanned as a fallback) and the program's predicted
    /// cycles-per-row; the row split then equalizes predicted completion
    /// time across the claimed devices (`sched::weighted_shards`) instead
    /// of splitting evenly. `None` = unconstrained even split. Shard
    /// outputs stitch in ascending row order either way, so the split
    /// weights can never affect results (bit-identity is pinned by
    /// `tests/sched_conformance.rs`).
    pub fn exec_row_sharded_weighted<T, E>(
        &self,
        home: Option<&Arc<Device>>,
        rows: usize,
        out_width: usize,
        cost: Option<(u64, f64)>,
        exec: E,
    ) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        E: Fn(&Device, Range<usize>) -> anyhow::Result<Vec<T>> + Sync,
    {
        anyhow::ensure!(!self.devices.is_empty(), "fleet has no devices");
        if rows == 0 {
            return Ok(Vec::new());
        }
        let fingerprint = cost.map(|(fp, _)| fp);
        let mut leases: Vec<Lease> = Vec::new();
        if let Some(d) = home {
            if !d.is_failed() && d.eligible(fingerprint) {
                // The worker already holds this device; not ours to release.
                leases.push(Lease { dev: Arc::clone(d), owned: false });
            }
        }
        let exclude = leases.first().map(|l| l.dev.id).unwrap_or(usize::MAX);
        // How many shards could this batch even use? Claim at most that.
        let max_useful = plan_shards(rows, self.devices.len(), self.opts.shard_min_rows).len();
        if max_useful > leases.len() {
            leases.extend(self.claim_idle(exclude, max_useful - leases.len(), fingerprint));
        }
        let devlist: Vec<Arc<Device>> = if leases.is_empty() {
            // Home dropped (or absent) and nothing idle to claim: fall back
            // to the first eligible device — `run_one_shard` skips dropped
            // and ineligible devices and scans the whole fleet, so this is
            // only a starting point.
            let d = self
                .devices
                .iter()
                .find(|d| d.eligible(fingerprint))
                .unwrap_or(&self.devices[0]);
            vec![Arc::clone(d)]
        } else {
            leases.iter().map(|l| Arc::clone(&l.dev)).collect()
        };
        // Assign ranges to devices: cost-weighted when a cycle model is in
        // hand, even otherwise. Either way the ranges are contiguous,
        // ascending and cover 0..rows — the stitching invariant.
        let assignments: Vec<(usize, Range<usize>)> = match cost {
            Some((_, cycles_per_row)) if devlist.len() > 1 => {
                let preds: Vec<super::sched::DevicePrediction> = devlist
                    .iter()
                    .map(|d| super::sched::DevicePrediction {
                        pending_cycles: d.pending_cycles() as f64,
                        cycles_per_row,
                    })
                    .collect();
                super::sched::weighted_shards(rows, self.opts.shard_min_rows, &preds)
            }
            _ => plan_shards(rows, devlist.len(), self.opts.shard_min_rows)
                .into_iter()
                .enumerate()
                .collect(),
        };
        let results: Vec<anyhow::Result<Vec<T>>> = if assignments.len() <= 1 {
            assignments
                .iter()
                .map(|(i, r)| self.run_one_shard(&devlist, *i, r.clone(), fingerprint, &exec))
                .collect()
        } else {
            let devlist_ref = &devlist;
            let exec_ref = &exec;
            std::thread::scope(|s| {
                let handles: Vec<_> = assignments
                    .iter()
                    .map(|(i, r)| {
                        let (first, range) = (*i, r.clone());
                        s.spawn(move || {
                            self.run_one_shard(devlist_ref, first, range, fingerprint, exec_ref)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join().unwrap_or_else(|_| {
                            Err(anyhow::anyhow!("shard thread panicked"))
                        })
                    })
                    .collect()
            })
        };
        let mut out: Vec<T> = Vec::with_capacity(rows * out_width);
        for ((_, r), res) in assignments.iter().zip(results) {
            let v = res?;
            anyhow::ensure!(
                v.len() == r.len() * out_width,
                "shard {}..{} returned {} items, expected {}",
                r.start,
                r.end,
                v.len(),
                r.len() * out_width
            );
            out.extend(v);
        }
        Ok(out)
    }

    /// Sharded element-typed program execution (the word serving path):
    /// bit-identical to single-device `execute_program_words` for every
    /// element backend, with zero runtime plan compiles (each shard reuses
    /// the program's precompiled plans via [`Program::shard_rows`]).
    ///
    /// Words always execute on the devices' persistent simulators (their
    /// plan caches), not through `TileExecutor::run_program_words`: no
    /// executor overrides the word path (f32 oracles cannot represent field
    /// arithmetic), and per-device simulator reuse is what keeps
    /// steady-state serving allocation- and compile-free.
    pub fn run_program_words(
        &self,
        home: Option<&Arc<Device>>,
        program: &Program,
        rows: usize,
        input: &[u64],
        weights: &WordWeights,
    ) -> anyhow::Result<Vec<u64>> {
        let kf = program.in_features();
        anyhow::ensure!(
            input.len() == rows * kf,
            "activation is {} words, expected {rows}×{kf}",
            input.len()
        );
        let cost = Some((
            crate::artifact::arch_fingerprint(&program.cfg),
            super::sched::cycles_per_row(program),
        ));
        self.exec_row_sharded_weighted(home, rows, program.out_features(), cost, |dev, r| {
            let shard = program.shard_rows(r);
            dev.run_program_words(program, shard.row_count(), &input[shard.input_words()], weights)
        })
    }

    /// Sharded f32 program execution (the f32 session path, through each
    /// device's executor backend).
    pub fn run_program(
        &self,
        home: Option<&Arc<Device>>,
        program: &Program,
        rows: usize,
        input: &[f32],
        weights: &Arc<Vec<Vec<f32>>>,
    ) -> anyhow::Result<Vec<f32>> {
        let kf = program.in_features();
        anyhow::ensure!(
            input.len() == rows * kf,
            "activation is {} elements, expected {rows}×{kf}",
            input.len()
        );
        let cost = Some((
            crate::artifact::arch_fingerprint(&program.cfg),
            super::sched::cycles_per_row(program),
        ));
        self.exec_row_sharded_weighted(home, rows, program.out_features(), cost, |dev, r| {
            let shard = program.shard_rows(r);
            let out = dev.executor().run_program(
                program,
                shard.row_count(),
                &input[shard.input_words()],
                weights,
            )?;
            // Executor backends don't expose wave counts, but the modeled
            // stall share is program-derived and applies to any backend.
            dev.note_modeled(program, shard.row_count());
            Ok(out)
        })
    }

    /// Sharded ad-hoc GEMM execution: the M dimension splits across
    /// devices; each shard is an independent `(rows × K) · (K × N)` GEMM.
    pub fn gemm(
        &self,
        home: Option<&Arc<Device>>,
        m: usize,
        k: usize,
        n: usize,
        input: &[f32],
        weight: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            input.len() == m * k && weight.len() == k * n,
            "shape mismatch: input {} (want {m}×{k}), weight {} (want {k}×{n})",
            input.len(),
            weight.len()
        );
        self.exec_row_sharded(home, m, n, |dev, r| {
            dev.executor().gemm(r.len(), k, n, &input[r.start * k..r.end * k], weight)
        })
    }

    // ------------------------------------------------------------------
    // Deterministic fault injection (tests / `faults` feature).
    // ------------------------------------------------------------------

    /// Install a [`FaultPlan`]. Replaces any previous plan; faults start
    /// applying on the next shard execution.
    #[cfg(any(test, feature = "faults"))]
    pub fn set_fault_plan(&self, plan: FaultPlan) {
        let rng = crate::util::Lcg::new(plan.seed);
        *lock_clean(&self.faults) = Some(FaultState { plan, rng, shards_started: 0 });
    }

    /// Fault-injection hook, called once per shard execution (inside the
    /// shard runner's `catch_unwind`, so injected panics are contained the
    /// same way real executor panics are). No-op without an installed plan.
    #[cfg(any(test, feature = "faults"))]
    fn fault_point(&self, dev: &Device) {
        let (slow_ms, panic_now) = {
            let mut g = lock_clean(&self.faults);
            let Some(st) = g.as_mut() else { return };
            let n = st.shards_started;
            st.shards_started += 1;
            for d in &st.plan.dropouts {
                if d.after_shards == n {
                    if let Some(victim) = self.devices.get(d.device) {
                        victim.mark_failed(d.transient);
                    }
                }
            }
            let slow = st.plan.slow_prob > 0.0 && st.rng.f64() < st.plan.slow_prob;
            let panic_now = st.plan.panic_prob > 0.0 && st.rng.f64() < st.plan.panic_prob;
            (if slow { st.plan.slow_ms } else { 0 }, panic_now)
        };
        // The faults lock is released before sleeping or panicking: a
        // panic while holding it would serialize fault draws behind poison
        // clearing, and a sleep would stall every other shard's draw.
        if slow_ms > 0 {
            std::thread::sleep(Duration::from_millis(slow_ms));
        }
        if panic_now {
            panic!("injected executor fault (FaultPlan) on device {}", dev.id);
        }
    }

    /// Production stub: fault injection compiles out entirely.
    #[cfg(not(any(test, feature = "faults")))]
    #[inline(always)]
    fn fault_point(&self, _dev: &Device) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::{execute_program_words, NaiveExecutor};
    use crate::mapper::chain::Chain;
    use crate::mapper::search::MapperOptions;
    use crate::util::prop::forall;
    use crate::util::Lcg;

    fn fast() -> MapperOptions {
        MapperOptions { full_layout_search: false, threads: 1, ..Default::default() }
    }

    fn fleet(devices: usize, shard_min_rows: usize) -> Fleet {
        let cfg = ArchConfig::paper(4, 4);
        Fleet::new(
            &cfg,
            Arc::new(NaiveExecutor),
            FleetOptions { devices, shard_min_rows, ..Default::default() },
        )
    }

    #[test]
    fn plan_shards_cover_rows_contiguously() {
        forall("plan-shards-cover", 256, |g| {
            let rows = g.usize(1, 200);
            let max_shards = g.usize(1, 9);
            let min_rows = g.usize(1, 300);
            let shards = plan_shards(rows, max_shards, min_rows);
            assert!(!shards.is_empty());
            assert!(shards.len() <= max_shards);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, rows);
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
            }
            // Every shard honours the minimum (when rows allow one at all).
            if rows >= min_rows {
                for s in &shards {
                    assert!(s.len() >= min_rows, "{s:?} under min {min_rows}");
                }
            } else {
                assert_eq!(shards.len(), 1, "too few rows: one shard");
            }
        });
    }

    #[test]
    fn plan_shards_edges() {
        assert!(plan_shards(0, 4, 1).is_empty());
        // 1-row shards.
        assert_eq!(plan_shards(7, 7, 1).len(), 7);
        // min larger than the whole range → one shard.
        assert_eq!(plan_shards(5, 8, 1000), vec![0..5]);
        // max_shards = 0 is treated as 1.
        assert_eq!(plan_shards(5, 0, 1), vec![0..5]);
    }

    #[test]
    fn sharded_words_match_single_device_and_compile_nothing() {
        let f = fleet(3, 1);
        let chain = Chain::mlp("fleet", 5, &[8, 12, 8]);
        let p = Program::compile(&f.cfg, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(9);
        let ww = WordWeights::new(
            chain.layers.iter().map(|g| ElemType::Goldilocks.sample_words(&mut rng, g.k * g.n)).collect(),
            ElemType::Goldilocks,
        );
        for rows in [1usize, 5, 7, 16] {
            let input = ElemType::Goldilocks.sample_words(&mut rng, rows * p.in_features());
            let got = f.run_program_words(None, &p, rows, &input, &ww).unwrap();
            let want = execute_program_words(&p, rows, &input, &ww).unwrap();
            assert_eq!(got, want, "rows={rows}");
        }
        assert_eq!(f.plan_compiles(), 0, "precompiled plans only");
        let rep = f.report(1.0);
        assert!(rep.devices.iter().map(|d| d.shards).sum::<u64>() >= 4);
        // With 1-row minimum and 3 devices, the 16-row batch sharded.
        assert!(rep.devices.iter().filter(|d| d.shards > 0).count() >= 2, "{rep:?}");
    }

    #[test]
    fn fleet_stall_accounting_sums_to_the_program_model() {
        // Live stall accounting: shards covering exactly the program's row
        // count charge, in total, exactly the program's modeled cycles —
        // regardless of how the rows split across devices.
        let f = fleet(2, 1);
        let chain = Chain::mlp("stall", 4, &[8, 12, 8]);
        let p = Program::compile(&f.cfg, &chain, &fast()).unwrap();
        assert!(p.stall.is_populated());
        let mut rng = Lcg::new(21);
        let ww = WordWeights::new(
            chain.layers.iter().map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n)).collect(),
            ElemType::I32,
        );
        let input = ElemType::I32.sample_words(&mut rng, 4 * p.in_features());
        f.run_program_words(None, &p, 4, &input, &ww).unwrap();
        let rep = f.report(1.0);
        let m = rep.modeled();
        assert!(
            (m.minisa_total_cycles - p.stall.minisa_total_cycles).abs()
                < 1e-6 * p.stall.minisa_total_cycles.max(1.0),
            "fleet {} vs program {}",
            m.minisa_total_cycles,
            p.stall.minisa_total_cycles
        );
        assert!(
            (m.micro_fetch_stall_cycles - p.stall.micro_fetch_stall_cycles).abs()
                < 1e-6 * p.stall.micro_fetch_stall_cycles.max(1.0)
        );
        // The word path also counts the waves its simulators issued.
        let waves: u64 = rep.devices.iter().map(|d| d.waves).sum();
        assert!(waves > 0, "{rep:?}");
        // The rendered report surfaces the live stall table.
        assert!(rep.render().contains("micro-fetch-stall"), "{}", rep.render());
    }

    #[test]
    fn sharded_gemm_matches_unsharded() {
        let f = fleet(3, 2);
        let mut rng = Lcg::new(4);
        let (m, k, n) = (10usize, 6usize, 5usize);
        let iv = rng.f32_matrix(m, k);
        let wv = rng.f32_matrix(k, n);
        let got = f.gemm(None, m, k, n, &iv, &wv).unwrap();
        let want = NaiveExecutor.gemm(m, k, n, &iv, &wv).unwrap();
        assert_eq!(got, want);
        assert!(f.gemm(None, m, k, n, &iv[1..], &wv).is_err(), "shape mismatch rejected");
    }

    #[test]
    fn dropout_requeues_on_survivors() {
        let f = fleet(2, 1);
        let chain = Chain::mlp("fleet", 4, &[8, 8]);
        let p = Program::compile(&f.cfg, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(5);
        let ww = WordWeights::new(
            chain.layers.iter().map(|g| ElemType::BabyBear.sample_words(&mut rng, g.k * g.n)).collect(),
            ElemType::BabyBear,
        );
        assert!(f.fail_device(0));
        assert!(!f.fail_device(99));
        let input = ElemType::BabyBear.sample_words(&mut rng, 8 * p.in_features());
        let got = f.run_program_words(None, &p, 8, &input, &ww).unwrap();
        let want = execute_program_words(&p, 8, &input, &ww).unwrap();
        assert_eq!(got, want, "requeued work lands bit-exact");
        // The dropped device executed nothing; the survivor did everything.
        assert_eq!(f.devices()[0].stats().shards, 0);
        assert!(f.devices()[1].stats().shards >= 1);
    }

    #[test]
    fn all_devices_dropped_is_an_error_not_a_hang() {
        let f = fleet(2, 1);
        let chain = Chain::mlp("fleet", 4, &[8, 8]);
        let p = Program::compile(&f.cfg, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(6);
        let ww = WordWeights::new(
            chain.layers.iter().map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n)).collect(),
            ElemType::I32,
        );
        f.fail_device(0);
        f.fail_device(1);
        let input = ElemType::I32.sample_words(&mut rng, 4 * p.in_features());
        let e = f.run_program_words(None, &p, 4, &input, &ww).unwrap_err();
        assert!(e.to_string().contains("dropped"), "{e}");
    }

    #[test]
    fn leases_release_busy_slots() {
        let f = fleet(3, 1);
        {
            let leases = f.claim_idle(usize::MAX, 3, None);
            assert_eq!(leases.len(), 3);
            assert!(f.devices().iter().all(|d| d.is_busy()));
            // A second claim finds nothing idle.
            assert!(f.claim_idle(usize::MAX, 3, None).is_empty());
        }
        assert!(f.devices().iter().all(|d| !d.is_busy()), "leases restored availability");
    }

    #[test]
    fn transient_failure_recovers_after_probe() {
        let cfg = ArchConfig::paper(4, 4);
        let f = Fleet::new(
            &cfg,
            Arc::new(NaiveExecutor),
            FleetOptions { devices: 2, shard_min_rows: 1, probe_after_ms: 5, ..Default::default() },
        );
        assert!(f.fail_device_transient(0));
        assert!(f.devices()[0].is_failed());
        // Probe before the quarantine elapses: still out.
        f.probe_recover();
        assert!(f.devices()[0].is_failed());
        std::thread::sleep(Duration::from_millis(10));
        f.probe_recover();
        assert!(!f.devices()[0].is_failed(), "transient failure healed");
        assert_eq!(f.devices()[0].stats().recoveries, 1);
        // Permanent failures never heal.
        assert!(f.fail_device(1));
        std::thread::sleep(Duration::from_millis(10));
        f.probe_recover();
        assert!(f.devices()[1].is_failed(), "permanent dropout stays out");
        // And a later transient mark cannot downgrade it.
        f.fail_device_transient(1);
        std::thread::sleep(Duration::from_millis(10));
        f.probe_recover();
        assert!(f.devices()[1].is_failed());
    }

    #[test]
    fn watchdog_trips_retry_on_another_device_bit_exact() {
        // Device work is made artificially slow with a FaultPlan that hits
        // (deterministically) every shard; the watchdog quarantines the
        // slow device and the retry must land bit-exact on a survivor.
        let cfg = ArchConfig::paper(4, 4);
        let f = Fleet::new(
            &cfg,
            Arc::new(NaiveExecutor),
            FleetOptions {
                devices: 2,
                shard_min_rows: 64, // keep the batch on one shard
                shard_timeout_ms: 5,
                retry_budget: 3,
                probe_after_ms: 1000,
                ..Default::default()
            },
        );
        let chain = Chain::mlp("wd", 4, &[8, 8]);
        let p = Program::compile(&f.cfg, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(11);
        let ww = WordWeights::new(
            chain.layers.iter().map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n)).collect(),
            ElemType::I32,
        );
        let input = ElemType::I32.sample_words(&mut rng, 4 * p.in_features());
        let want = execute_program_words(&p, 4, &input, &ww).unwrap();
        f.set_fault_plan(FaultPlan { seed: 1, slow_prob: 1.0, slow_ms: 20, ..Default::default() });
        // Every execution is slow, so the budget must eventually give up...
        let e = f.run_program_words(None, &p, 4, &input, &ww).unwrap_err();
        assert!(e.to_string().starts_with("watchdog:"), "{e}");
        let trips: u64 = f.devices().iter().map(|d| d.stats().watchdog_trips).sum();
        assert!(trips >= 1, "watchdog tripped");
        // ...but with the fault cleared and the devices healed, the same
        // batch executes cleanly and bit-exact.
        for d in f.devices() {
            d.maybe_recover(Duration::from_millis(0));
        }
        f.set_fault_plan(FaultPlan { seed: 1, slow_prob: 0.0, ..Default::default() });
        let got = f.run_program_words(None, &p, 4, &input, &ww).unwrap();
        assert_eq!(got, want, "post-recovery execution is bit-exact");
    }

    #[test]
    fn fault_plan_scripted_dropout_and_panic_are_contained() {
        let f = fleet(3, 1);
        let chain = Chain::mlp("fp", 6, &[8, 8]);
        let p = Program::compile(&f.cfg, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(12);
        let ww = WordWeights::new(
            chain.layers.iter().map(|g| ElemType::Goldilocks.sample_words(&mut rng, g.k * g.n)).collect(),
            ElemType::Goldilocks,
        );
        let input = ElemType::Goldilocks.sample_words(&mut rng, 6 * p.in_features());
        let want = execute_program_words(&p, 6, &input, &ww).unwrap();
        // Scripted: drop device 1 permanently before the second shard.
        f.set_fault_plan(FaultPlan {
            seed: 3,
            dropouts: vec![FaultDropout { device: 1, after_shards: 1, transient: false }],
            ..Default::default()
        });
        let got = f.run_program_words(None, &p, 6, &input, &ww).unwrap();
        assert_eq!(got, want, "dropout mid-stream stays bit-exact");
        assert!(f.devices()[1].is_failed());
        // Panic injection: always panics → typed error, busy slots intact.
        f.set_fault_plan(FaultPlan { seed: 4, panic_prob: 1.0, ..Default::default() });
        let e = f.run_program_words(None, &p, 6, &input, &ww).unwrap_err();
        assert!(e.to_string().contains("panicked"), "{e}");
        assert!(f.devices().iter().all(|d| !d.is_busy()), "no leaked busy slots");
    }

    #[test]
    fn workers_shut_down_promptly_without_timed_polls() {
        let cfg = ArchConfig::paper(4, 4);
        let f = Arc::new(Fleet::new(
            &cfg,
            Arc::new(NaiveExecutor),
            FleetOptions { devices: 3, shard_min_rows: 1, ..Default::default() },
        ));
        f.start_workers();
        assert!(f.workers_active());
        // Jobs submitted before shutdown all run (the counter proves no
        // job is lost in the scan-then-park window).
        let ran = Arc::new(AtomicU64::new(0));
        for i in 0..64u64 {
            let ran = Arc::clone(&ran);
            f.submit(i, Box::new(move |_d| {
                ran.fetch_add(1, Ordering::Relaxed);
            }));
        }
        let t0 = Instant::now();
        f.shutdown();
        assert_eq!(ran.load(Ordering::Relaxed), 64);
        // Bounded shutdown: parked workers wake on the shutdown event, not
        // on a poll tick. Generous bound to stay robust on loaded CI.
        assert!(t0.elapsed() < Duration::from_secs(2), "{:?}", t0.elapsed());
        assert!(!f.workers_active());
    }

    fn hetero_fleet(archs: &[ArchConfig]) -> Fleet {
        Fleet::with_archs(
            archs,
            Arc::new(NaiveExecutor),
            FleetOptions { shard_min_rows: 1, ..Default::default() },
        )
    }

    #[test]
    fn with_archs_builds_one_device_per_arch() {
        let archs = [ArchConfig::paper(4, 4), ArchConfig::paper(4, 8), ArchConfig::paper(4, 4)];
        let f = hetero_fleet(&archs);
        assert_eq!(f.device_count(), 3);
        assert_eq!(f.cfg, archs[0], "device 0's arch is the fleet default");
        for (d, a) in f.devices().iter().zip(&archs) {
            assert_eq!(d.arch(), a);
            assert_eq!(d.fingerprint(), crate::artifact::arch_fingerprint(a));
        }
        // Same arch → same fingerprint (devices 0 and 2 form one group).
        assert_eq!(f.devices()[0].fingerprint(), f.devices()[2].fingerprint());
        assert_ne!(f.devices()[0].fingerprint(), f.devices()[1].fingerprint());
        // Eligibility: constrained work only matches its own group;
        // unconstrained work runs anywhere.
        let fp0 = f.devices()[0].fingerprint();
        assert!(f.devices()[0].eligible(Some(fp0)));
        assert!(!f.devices()[1].eligible(Some(fp0)));
        assert!(f.devices()[1].eligible(None));
    }

    /// Regression (ISSUE 9): work stealing used to ignore session/device
    /// compatibility — a steal from an incompatible device must be refused
    /// and the job left queued for an eligible device.
    #[test]
    fn steal_refuses_incompatible_job_until_victim_fails() {
        let f = hetero_fleet(&[ArchConfig::paper(4, 4), ArchConfig::paper(4, 8)]);
        let fp0 = f.devices()[0].fingerprint();
        let ran = Arc::new(AtomicU64::new(0));
        let ran_c = Arc::clone(&ran);
        f.submit_eligible(0, Some(fp0), 100, Box::new(move |_d| {
            ran_c.fetch_add(1, Ordering::Relaxed);
        }));
        // The job landed on device 0 (the only eligible device) and charged
        // its predicted cost to that queue.
        assert_eq!(lock_clean(&f.devices()[0].queue).len(), 1);
        assert_eq!(f.devices()[0].pending_cycles(), 100);
        // Device 1 (wrong arch) scans for work: the steal must be refused
        // while the victim is alive — the job stays queued.
        assert!(f.next_job(&f.devices()[1]).is_none(), "incompatible steal refused");
        assert_eq!(lock_clean(&f.devices()[0].queue).len(), 1, "job still queued");
        assert_eq!(ran.load(Ordering::Relaxed), 0);
        // Once the victim drops, anyone may rescue the job (the execution
        // path enforces eligibility itself and answers with a typed error),
        // so its requests are never stranded in a dead queue.
        f.fail_device(0);
        let (job, stolen, from_failed) =
            f.next_job(&f.devices()[1]).expect("rescue from failed victim");
        assert!(stolen && from_failed);
        assert_eq!(f.devices()[0].pending_cycles(), 0, "cost discharged on rescue");
        (job.job)(&f.devices()[1]);
        assert_eq!(ran.load(Ordering::Relaxed), 1, "rescued job ran");
    }

    #[test]
    fn submit_eligible_prefers_least_loaded_eligible_device() {
        let f = hetero_fleet(&[
            ArchConfig::paper(4, 4),
            ArchConfig::paper(4, 4),
            ArchConfig::paper(4, 8),
        ]);
        let fp = f.devices()[0].fingerprint();
        // Pre-load device 0 with pending predicted work.
        f.devices()[0].charge_pending(10_000);
        f.submit_eligible(0, Some(fp), 500, Box::new(|_d| {}));
        // Device 1 is eligible and idle → the job lands there, not on the
        // loaded device 0 and never on the wrong-arch device 2.
        assert_eq!(lock_clean(&f.devices()[1].queue).len(), 1);
        assert_eq!(f.devices()[1].pending_cycles(), 500);
        assert_eq!(lock_clean(&f.devices()[2].queue).len(), 0);
    }

    #[test]
    fn hetero_sharding_executes_only_on_matching_arch() {
        // Program compiled for the 4x8 device of a mixed fleet: sharded
        // execution must only ever touch the matching device, and stays
        // bit-identical to the single-device reference.
        let f = hetero_fleet(&[ArchConfig::paper(4, 4), ArchConfig::paper(4, 8)]);
        let other = ArchConfig::paper(4, 8);
        let chain = Chain::mlp("hetero", 6, &[8, 8]);
        let p = Program::compile(&other, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(31);
        let ww = WordWeights::new(
            chain.layers.iter().map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n)).collect(),
            ElemType::I32,
        );
        let input = ElemType::I32.sample_words(&mut rng, 6 * p.in_features());
        let got = f.run_program_words(None, &p, 6, &input, &ww).unwrap();
        let want = execute_program_words(&p, 6, &input, &ww).unwrap();
        assert_eq!(got, want, "hetero placement is bit-exact");
        assert_eq!(f.devices()[0].stats().shards, 0, "wrong-arch device untouched");
        assert!(f.devices()[1].stats().shards >= 1);
        // Drop the only eligible device: a typed placement error, not a
        // hang and never a wrong-arch execution.
        f.fail_device(1);
        let e = f.run_program_words(None, &p, 6, &input, &ww).unwrap_err();
        assert!(e.to_string().starts_with("no eligible device"), "{e}");
        assert_eq!(f.devices()[0].stats().shards, 0, "still untouched after dropout");
    }

    #[test]
    fn mixed_backends_share_one_device_plan_cache() {
        // One fleet serves Goldilocks then BabyBear then i32 programs; each
        // backend gets its own persistent simulator per device and nothing
        // recompiles.
        let f = fleet(2, 1);
        let chain = Chain::mlp("fleet", 4, &[8, 8]);
        let p = Program::compile(&f.cfg, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(7);
        for elem in [ElemType::Goldilocks, ElemType::BabyBear, ElemType::I32] {
            let ww = WordWeights::new(
                chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect(),
                elem,
            );
            let input = elem.sample_words(&mut rng, 6 * p.in_features());
            let got = f.run_program_words(None, &p, 6, &input, &ww).unwrap();
            let want = execute_program_words(&p, 6, &input, &ww).unwrap();
            assert_eq!(got, want, "{elem}");
        }
        assert_eq!(f.plan_compiles(), 0);
    }
}
