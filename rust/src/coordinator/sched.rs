//! Cost-aware scheduling — perf-model-driven placement and row-split
//! weighting for heterogeneous fleets (§Sched tentpole, ROADMAP item 3).
//!
//! The fleet's two dispatch granularities both consult the same cost
//! model here:
//!
//! * **Placement** (request-parallel): [`predict_cycles`] prices a batch
//!   on a device from the program's compile-time perf model
//!   ([`Program::total_cycles`], the `perf/` 5-engine pipeline), and the
//!   fleet routes to the eligible device whose queue finishes earliest
//!   under the prediction (pending predicted cycles + this batch).
//!   Eligibility is strict arch-fingerprint equality — a compiled
//!   program's plans encode one `ArchConfig`'s addressing, so running it
//!   anywhere else is a correctness error, not a slowdown.
//! * **Row splitting** (tile-parallel): [`weighted_shards`] replaces the
//!   even `plan_shards` split with a completion-time waterfill — each
//!   device's share is sized so all shards are predicted to finish
//!   together, accounting for the work already queued on each device
//!   ([`DevicePrediction::pending_cycles`]) and its per-row cost.
//!
//! Both functions are pure and deterministic: same inputs → same
//! placement, which is what lets `tests/sched_conformance.rs` pin the
//! stitch order and prove bit-identity against single-device execution.

// Hot-file lint escalation (§Perf CI satellite) — see plan.rs.
#![deny(clippy::needless_range_loop, clippy::manual_memcpy)]

use std::ops::Range;

use crate::program::Program;

/// Predicted cycles to execute `rows` activation rows of `program` on a
/// device of the program's own arch. Chunked execution replays the whole
/// compiled chain once per `ceil(rows / m)` chunk of the compiled row
/// height `m` (`execute_program_words_blocked`), so partial chunks cost a
/// full pass — the honest step function, not a smooth rate.
pub fn predict_cycles(program: &Program, rows: usize) -> f64 {
    if rows == 0 {
        return 0.0;
    }
    let m = program.rows().max(1);
    program.total_cycles * rows.div_ceil(m) as f64
}

/// Smooth per-row cycle rate of `program` — the waterfill weight for
/// [`weighted_shards`] (the step function of [`predict_cycles`] is not
/// invertible; the rate is its dense-batch limit).
pub fn cycles_per_row(program: &Program) -> f64 {
    program.total_cycles / program.rows().max(1) as f64
}

/// One device's scheduling inputs for [`weighted_shards`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DevicePrediction {
    /// Predicted cycles of work already queued on (or executing on) the
    /// device — the completion-time head start it must amortize.
    pub pending_cycles: f64,
    /// Predicted cycles per activation row for the program being split
    /// (uniform across a fingerprint-eligible set, but kept per-device so
    /// the waterfill generalizes).
    pub cycles_per_row: f64,
}

impl DevicePrediction {
    /// Predicted completion time if this device were handed `rows` rows.
    fn completion(&self, rows: usize) -> f64 {
        self.pending_cycles + rows as f64 * self.cycles_per_row.max(0.0)
    }
}

/// Split `rows` contiguous activation rows across the devices of `preds`
/// so that every shard is predicted to **finish at the same time**:
/// device `d` gets `s_d = (T − pending_d) / cycles_per_row_d` rows, with
/// the common completion time `T` chosen so the shares sum to `rows`
/// (devices whose backlog already exceeds `T` get nothing). Returns
/// `(device_index, row_range)` pairs — indices into `preds` — with ranges
/// contiguous, ascending, covering `0..rows` exactly and assigned to
/// devices in ascending index order (the pinned stitch order). Every
/// returned shard has at least `min_rows` rows unless `rows < min_rows`
/// (then one shard carries everything). Deterministic: ties break on the
/// lower device index.
pub fn weighted_shards(
    rows: usize,
    min_rows: usize,
    preds: &[DevicePrediction],
) -> Vec<(usize, Range<usize>)> {
    if rows == 0 || preds.is_empty() {
        return Vec::new();
    }
    let min_rows = min_rows.max(1);
    let n_max = (rows / min_rows).clamp(1, preds.len());
    // Candidate devices: the n_max least-loaded (they can absorb the most
    // rows before the fleet equalizes), ties on index for determinism.
    let mut order: Vec<usize> = (0..preds.len()).collect();
    order.sort_by(|&a, &b| {
        preds[a]
            .pending_cycles
            .total_cmp(&preds[b].pending_cycles)
            .then(a.cmp(&b))
    });
    order.truncate(n_max);
    if order.len() == 1 || rows < 2 * min_rows {
        // Nothing to split: the whole batch goes to the device that
        // finishes it earliest.
        let best = *order
            .iter()
            .min_by(|&&a, &&b| {
                preds[a]
                    .completion(rows)
                    .total_cmp(&preds[b].completion(rows))
                    .then(a.cmp(&b))
            })
            .expect("order is non-empty");
        return vec![(best, 0..rows)];
    }
    // Waterfill: with candidates sorted by pending ascending, find the
    // largest prefix k whose common completion time T_k clears every
    // member's backlog. Degenerate rates (cycles_per_row ≤ 0) mean "cost
    // unknown" — fall back to weight 1 so the split degrades to
    // pending-blind near-even sharing instead of dividing by zero.
    let rate = |i: usize| {
        let c = preds[i].cycles_per_row;
        if c > 0.0 {
            c
        } else {
            1.0
        }
    };
    let mut shares = vec![0.0f64; preds.len()];
    for k in (1..=order.len()).rev() {
        let prefix = &order[..k];
        let inv_sum: f64 = prefix.iter().map(|&i| 1.0 / rate(i)).sum();
        let load_sum: f64 = prefix.iter().map(|&i| preds[i].pending_cycles / rate(i)).sum();
        let t = (rows as f64 + load_sum) / inv_sum;
        let worst = preds[prefix[k - 1]].pending_cycles;
        if t >= worst || k == 1 {
            for &i in prefix {
                shares[i] = ((t - preds[i].pending_cycles) / rate(i)).max(0.0);
            }
            break;
        }
    }
    // Integer rounding: floors, then distribute the remainder by largest
    // fractional part (ties on lower index).
    let mut ishares: Vec<usize> = shares.iter().map(|s| s.floor() as usize).collect();
    let assigned: usize = ishares.iter().sum();
    let mut rem = rows.saturating_sub(assigned);
    let mut frac_order: Vec<usize> = order.clone();
    frac_order.sort_by(|&a, &b| {
        (shares[b] - shares[b].floor())
            .total_cmp(&(shares[a] - shares[a].floor()))
            .then(a.cmp(&b))
    });
    let mut fi = 0usize;
    while rem > 0 {
        ishares[frac_order[fi % frac_order.len()]] += 1;
        rem -= 1;
        fi += 1;
    }
    // Enforce the per-shard minimum: fold undersized shares into the
    // current largest share (ties on lower index) until every non-zero
    // share clears min_rows.
    loop {
        let Some(small) = (0..ishares.len())
            .filter(|&i| ishares[i] > 0 && ishares[i] < min_rows)
            .min_by_key(|&i| (ishares[i], i))
        else {
            break;
        };
        let big = (0..ishares.len())
            .filter(|&i| i != small && ishares[i] > 0)
            .max_by_key(|&i| (ishares[i], usize::MAX - i));
        match big {
            Some(b) => {
                ishares[b] += ishares[small];
                ishares[small] = 0;
            }
            None => break, // only one non-zero share: keep it whatever its size
        }
    }
    debug_assert_eq!(ishares.iter().sum::<usize>(), rows);
    // Ranges in ascending device-index order — the pinned stitch order.
    let mut out = Vec::new();
    let mut r0 = 0usize;
    for (i, &s) in ishares.iter().enumerate() {
        if s == 0 {
            continue;
        }
        out.push((i, r0..r0 + s));
        r0 += s;
    }
    debug_assert_eq!(r0, rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::mapper::chain::Chain;
    use crate::mapper::search::MapperOptions;
    use crate::util::prop::forall;

    fn pred(pending: f64, cpr: f64) -> DevicePrediction {
        DevicePrediction { pending_cycles: pending, cycles_per_row: cpr }
    }

    fn check_invariants(rows: usize, min_rows: usize, out: &[(usize, Range<usize>)]) {
        assert!(!out.is_empty());
        assert_eq!(out[0].1.start, 0);
        assert_eq!(out.last().unwrap().1.end, rows);
        for w in out.windows(2) {
            assert_eq!(w[0].1.end, w[1].1.start, "contiguous");
            assert!(w[0].0 < w[1].0, "ascending device order (stitch pin)");
        }
        let total: usize = out.iter().map(|(_, r)| r.len()).sum();
        assert_eq!(total, rows, "rows conserved");
        if out.len() > 1 {
            for (i, r) in out {
                assert!(r.len() >= min_rows.max(1), "dev{i} shard {r:?} under min {min_rows}");
            }
        }
    }

    #[test]
    fn weighted_shards_conserve_rows_under_arbitrary_loads() {
        forall("weighted-shards-conserve", 256, |g| {
            let rows = g.usize(1, 300);
            let min_rows = g.usize(1, 40);
            let n = g.usize(1, 6);
            let preds: Vec<DevicePrediction> = (0..n)
                .map(|_| pred(g.usize(0, 100_000) as f64, g.usize(1, 500) as f64))
                .collect();
            let out = weighted_shards(rows, min_rows, &preds);
            check_invariants(rows, min_rows, &out);
            for (i, _) in &out {
                assert!(*i < n, "device index in range");
            }
            // Deterministic.
            assert_eq!(out, weighted_shards(rows, min_rows, &preds));
        });
    }

    #[test]
    fn even_fleet_splits_evenly() {
        let preds = vec![pred(0.0, 10.0); 4];
        let out = weighted_shards(100, 1, &preds);
        assert_eq!(out.len(), 4);
        for (_, r) in &out {
            assert_eq!(r.len(), 25);
        }
    }

    #[test]
    fn loaded_device_gets_fewer_rows() {
        // Device 1 starts 500 cycles behind at 10 cycles/row: it should
        // get 50 fewer rows than device 0 (waterfill equalization).
        let preds = vec![pred(0.0, 10.0), pred(500.0, 10.0)];
        let out = weighted_shards(100, 1, &preds);
        assert_eq!(out.len(), 2);
        let s0 = out[0].1.len();
        let s1 = out[1].1.len();
        assert_eq!(s0 + s1, 100);
        assert_eq!(s0 as i64 - s1 as i64, 50, "{out:?}");
    }

    #[test]
    fn swamped_device_gets_nothing() {
        let preds = vec![pred(0.0, 10.0), pred(1e12, 10.0)];
        let out = weighted_shards(40, 1, &preds);
        assert_eq!(out, vec![(0, 0..40)]);
    }

    #[test]
    fn faster_arch_gets_more_rows() {
        // Device 1 costs 4× per row: the waterfill gives device 0 ~4× the
        // rows so both finish together.
        let preds = vec![pred(0.0, 10.0), pred(0.0, 40.0)];
        let out = weighted_shards(100, 1, &preds);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.len(), 80, "{out:?}");
        assert_eq!(out[1].1.len(), 20, "{out:?}");
    }

    #[test]
    fn min_rows_folds_slivers() {
        // 10 rows over 3 devices with min 4: no 3-way split exists, the
        // fold must leave every shard ≥ 4 and conserve rows.
        let preds = vec![pred(0.0, 10.0); 3];
        let out = weighted_shards(10, 4, &preds);
        check_invariants(10, 4, &out);
        assert!(out.len() <= 2, "{out:?}");
    }

    #[test]
    fn tiny_batch_is_one_shard_on_the_earliest_finisher() {
        let preds = vec![pred(900.0, 10.0), pred(100.0, 10.0), pred(500.0, 10.0)];
        let out = weighted_shards(3, 8, &preds);
        assert_eq!(out, vec![(1, 0..3)], "earliest completion wins the whole batch");
        assert!(weighted_shards(0, 1, &preds).is_empty());
        assert!(weighted_shards(5, 1, &[]).is_empty());
    }

    #[test]
    fn degenerate_rates_fall_back_to_even_sharing() {
        let preds = vec![pred(0.0, 0.0), pred(0.0, 0.0)];
        let out = weighted_shards(64, 1, &preds);
        check_invariants(64, 1, &out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.len(), 32);
    }

    #[test]
    fn predict_cycles_charges_whole_chain_passes() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("sched", 4, &[8, 8]);
        let opts = MapperOptions { full_layout_search: false, threads: 1, ..Default::default() };
        let p = Program::compile(&cfg, &chain, &opts).unwrap();
        assert_eq!(predict_cycles(&p, 0), 0.0);
        let one = predict_cycles(&p, 4); // exactly one chunk
        assert!(one > 0.0);
        assert_eq!(one, p.total_cycles);
        // Partial chunks round up: 5 rows = 2 passes, 8 rows = 2 passes.
        assert_eq!(predict_cycles(&p, 5), 2.0 * p.total_cycles);
        assert_eq!(predict_cycles(&p, 8), 2.0 * p.total_cycles);
        // The smooth rate times the chunk height recovers one pass.
        assert!((cycles_per_row(&p) * 4.0 - p.total_cycles).abs() < 1e-9);
    }
}
