//! FEATHER+ Mapper — mapping-first, layout-second (mapping, layout)
//! co-search (§V, Tab. VII).
//!
//! Pipeline (Fig. 8 / §V-B):
//! 1. lower the GEMM into Virtual Neurons,
//! 2. tile the workload (`M_t, K_t, N_t`),
//! 3. form VN groups (one streamed VN + up to AH stationary VNs),
//! 4. combine groups across streamed VNs (stationary reuse),
//! 5. select column duplication,
//! 6. search feasible buffer layouts (orders + level-0 factors),
//! 7. lower the winner to a MINISA trace and score it on the analytical
//!    performance model.
//!
//! The three mapping knobs — compute-tile size, VN-group formation
//! (`nbc` = distinct output-column blocks per invocation period) and column
//! duplication (`dup`) — parameterize every legal Eq.-(1) placement this
//! lowering emits.

pub mod chain;
pub mod exec;
pub mod lower;
pub mod search;

pub use lower::{lower_gemm, LoweredProgram};
pub use search::{search, MapperOptions};

use crate::mapping::Dataflow;
use crate::perf::PerfReport;

/// One candidate mapping (pre-layout): the paper's three knobs plus the
/// dataflow choice and VN size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MappingChoice {
    pub df: Dataflow,
    /// VN size (= reduction-L0 factor), ≤ AH.
    pub vn: usize,
    /// Tile extents in *search space* coordinates (WO-S: (M,K,N) as given;
    /// IO-S: M and N swapped — §V-B "IO-S is a transposed WO-S").
    pub m_t: usize,
    pub k_t: usize,
    pub n_t: usize,
    /// Distinct output-column blocks (AH-wide in n) per invocation period.
    pub nbc: usize,
    /// Column duplication factor (streamed-VN splitting).
    pub dup: usize,
}

impl MappingChoice {
    /// Reduction tiles resident per compute tile.
    pub fn kg_t(&self) -> usize {
        crate::util::ceil_div(self.k_t, self.vn)
    }

    /// Output-column blocks per compute tile (AH-element n blocks).
    pub fn nb_t(&self, ah: usize) -> usize {
        crate::util::ceil_div(self.n_t, ah)
    }

    /// Columns occupied per invocation period.
    pub fn period(&self) -> usize {
        self.nbc * self.dup
    }
}

/// A fully-resolved (mapping, layout) decision with its estimated cost.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub choice: MappingChoice,
    /// Tab. III order ids for the streamed, stationary and output layouts.
    pub i_order: u8,
    pub w_order: u8,
    pub o_order: u8,
    pub report: PerfReport,
}

impl Decision {
    pub fn latency_cycles(&self) -> f64 {
        self.report.total_cycles
    }
}
