//! Steps 2–6: candidate enumeration, feasibility checks and latency-driven
//! selection (§V-B, Tab. VII), parallelized across worker threads.
//!
//! The mapping space is parameterized by three knobs (tile size, VN-group
//! formation `nbc`, duplication `dup`) plus the dataflow bit; layouts are
//! then searched over Tab. III orders for the streamed and output tensors.
//! Candidates that violate buffer capacity are discarded (step 6a);
//! streaming-row-block and OB-pressure serialization enter the latency
//! estimate rather than hard rejection (FEATHER+'s crossbar makes them
//! legal-but-slower, §V-B6b/c).

use super::lower::{
    ob_pressure_factor, output_layout, search_dims, stationary_layout, streamed_layout,
};
use super::{Decision, MappingChoice};
use crate::arch::config::ArchConfig;
use crate::isa::bitwidth::IsaBitwidths;
use crate::mapping::{Dataflow, MappingCfg, StreamCfg};
use crate::perf::PerfReport;
use crate::util::ceil_div;
use crate::workloads::Gemm;

thread_local! {
    /// Per-thread count of mapper searches. Every entry into
    /// [`search_constrained`] (and therefore [`search`], chain compilation
    /// and the serving shape cache) bumps it; nothing else does. The
    /// artifact loading path (`Program::from_artifact`) asserts this
    /// counter does not move across a load — the literal form of the "zero
    /// mapper runs at load" guarantee the `.minisa` design promises.
    /// Thread-local rather than process-global so the assertion cannot be
    /// tripped by *other* threads legitimately compiling (e.g. parallel
    /// tests, or a serving leader compiling one session while another
    /// loads) — compiles and loads both happen on their caller's thread.
    static SEARCHES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Mapper searches run so far **on the calling thread**.
pub fn searches_run() -> u64 {
    SEARCHES.with(|c| c.get())
}

/// Search configuration.
#[derive(Debug, Clone)]
pub struct MapperOptions {
    /// Search both dataflows (default) or only the M/N heuristic's pick.
    pub both_dataflows: bool,
    /// Search all 6×6 streamed/output order pairs for the finalists
    /// (otherwise a fixed good pair).
    pub full_layout_search: bool,
    /// Worker threads for candidate scoring and layout refinement.
    pub threads: usize,
    /// Instruction mode for the latency estimate: MINISA (true) or the
    /// micro-instruction baseline (false) — used for Fig. 10 comparisons.
    pub minisa: bool,
    /// Branch-and-bound pruning in phase-1 candidate scoring (default on;
    /// the `pruning_never_changes_winner` test runs with it off).
    pub phase1_prune: bool,
    /// Run phase-2 layout refinement with the seed's serial full-`estimate`
    /// loop instead of the parallel bounded search. Kept for the
    /// before/after hot-path benchmark and the determinism tests.
    pub refine_serial: bool,
}

impl Default for MapperOptions {
    fn default() -> Self {
        Self {
            both_dataflows: true,
            full_layout_search: true,
            threads: 4,
            minisa: true,
            phase1_prune: true,
            refine_serial: false,
        }
    }
}

/// Phase-1 branch-and-bound slack. The seed pruned against `4 × best`,
/// which is sound for finding the *single* best candidate but not for
/// building the phase-2 finalist *pool*: a pruned candidate can hold a
/// top-16 phase-1 score and its absence reshuffles pool membership, so
/// pruning could change the selected winner. The bound is therefore taken
/// against the thread-local **16th-best** score (`FINALISTS`-th): a pruned
/// candidate has `score ≥ lb > slack · 16th ≥ 16th`, so it can never enter
/// the pool, making pruning provably winner-preserving (see
/// `pruning_never_changes_winner`). The 4× slack on top is intentional
/// headroom: phase-2 layout refinement can close modeled serialization
/// (stream-block / OB-pressure factors) that the fixed phase-1 layout pair
/// overestimates, and the slack keeps such candidates' scores exact rather
/// than lower-bounded.
pub const PHASE1_BOUND_SLACK: f64 = 4.0;

/// Finalist-pool size carried from phase 1 into phase-2 layout refinement.
pub const FINALISTS: usize = 16;

/// Closed-form pipeline estimate for one candidate (steady-state bound of
/// the engine pipeline in `perf::simulate`; exact for uniform tiles).
pub fn estimate(
    cfg: &ArchConfig,
    g: &Gemm,
    choice: &MappingChoice,
    i_order: u8,
    o_order: u8,
    minisa: bool,
) -> Option<PerfReport> {
    estimate_bounded(cfg, g, choice, i_order, o_order, minisa, f64::INFINITY)
}

/// `estimate` with branch-and-bound pruning: returns `None` early when the
/// probe-free lower bound (serialization factors only *increase* latency)
/// already exceeds `bound` (§Perf optimization).
#[allow(clippy::too_many_arguments)]
pub fn estimate_bounded(
    cfg: &ArchConfig,
    g: &Gemm,
    choice: &MappingChoice,
    i_order: u8,
    o_order: u8,
    minisa: bool,
    bound: f64,
) -> Option<PerfReport> {
    let (ms, ks, ns) = search_dims(g, choice.df);
    let vn = choice.vn;
    let ah = cfg.ah;
    let aw = cfg.aw;
    if vn > ah || choice.m_t == 0 || choice.k_t == 0 || choice.n_t == 0 {
        return None;
    }
    let mt = choice.m_t.min(ms);
    let kt = choice.k_t.min(ks);
    let nt = choice.n_t.min(ns);
    let kgt = ceil_div(kt, vn);
    let rows_active = vn.min(ah);
    let nbt = ceil_div(nt, rows_active);
    // Capacity feasibility (step 6a).
    let i_lay = streamed_layout(choice, mt, kgt, i_order);
    let w_lay = stationary_layout(cfg, choice, nt, kgt, 0);
    let (p_ext, q_ext) = match choice.df {
        Dataflow::WoS => (mt, nt),
        Dataflow::IoS => (nt, mt),
    };
    let o_lay = output_layout(cfg, choice, p_ext, q_ext, o_order);
    if !i_lay.fits(cfg.d_str(), aw) || !w_lay.fits(cfg.d_sta(), aw) || !o_lay.fits(cfg.d_ob(), aw)
    {
        return None;
    }
    // Interior-tile invocation structure.
    let period = (choice.nbc * choice.dup).min(aw).max(1);
    let kgc = (aw / period).max(1);
    let t_steps = ceil_div(mt, choice.dup).max(1) as u64;
    let inv_per_ktile = (ceil_div(nbt, choice.nbc) * ceil_div(kgt, kgc)) as u64;
    let n_tiles =
        (ceil_div(ms, choice.m_t) * ceil_div(ks, choice.k_t) * ceil_div(ns, choice.n_t)) as u64;
    let n_out_tiles = (ceil_div(ms, choice.m_t) * ceil_div(ns, choice.n_t)) as u64;
    let invocations = inv_per_ktile * n_tiles;
    let waves = invocations * t_steps;

    // Probe-free lower bound: factor >= 1, so compute-only + fixed engine
    // totals bound the final latency from below. Prune before the (more
    // expensive) per-wave probes when it cannot beat `bound`.
    let compute_lb = (waves * vn as u64) as f64 + (invocations * cfg.drain_cycles() as u64) as f64;
    if compute_lb >= bound {
        return None;
    }

    // Serialization factors probed on the interior tile.
    let em = MappingCfg { r0: 0, c0: 0, g_r: period, g_c: choice.nbc, s_r: 1, s_c: rows_active };
    let es = StreamCfg {
        df: choice.df,
        m0: 0,
        s_m: choice.dup,
        t: t_steps as usize,
        vn_size: vn,
    };
    let sf = super::lower::stream_block_factor(cfg, choice, &i_lay, &em, &es);
    let of = ob_pressure_factor(cfg, choice, &o_lay, &em, &es, p_ext, q_ext);
    let factor = sf.max(of) as u64;

    // Engine totals.
    let bw = IsaBitwidths::for_config(cfg);
    let instr_bits = if minisa {
        invocations * (bw.execute_mapping() + bw.execute_streaming()) as u64
            + n_tiles * (2 * bw.load_store() + 2 * bw.set_layout()) as u64
            + n_out_tiles * (bw.set_layout() + bw.load_store()) as u64
    } else {
        let mc = crate::microinst::cost(cfg, vn);
        waves * mc.bits_per_wave + invocations * mc.bits_per_invocation
    };
    let fetch = instr_bits as f64 / (cfg.instr_bw * 8.0);
    let load_in_words = (ms * ks) as f64 * ceil_div(ns, choice.n_t) as f64; // streamed reloaded per n-tile
    let load_w_words = (ks * ns) as f64 * ceil_div(ms, choice.m_t) as f64;
    let load = (load_in_words + load_w_words) * cfg.elem_bytes as f64 / cfg.data_bw_in;
    let compute = (waves * vn as u64 * factor) as f64
        + (invocations * cfg.drain_cycles() as u64) as f64;
    let out_words = (ms * ns) as f64;
    let out_stream = out_words / aw as f64;
    let store = out_words * cfg.acc_bytes as f64 / cfg.data_bw_out;

    let total = fetch.max(load).max(compute).max(out_stream).max(store);
    let stall_instr = (fetch - load.max(compute).max(store)).max(0.0);
    let stall_data = (load - compute.max(fetch).max(store)).max(0.0);
    Some(PerfReport {
        total_cycles: total,
        fetch_cycles: fetch,
        load_in_cycles: load_in_words * cfg.elem_bytes as f64 / cfg.data_bw_in,
        load_w_cycles: load_w_words * cfg.elem_bytes as f64 / cfg.data_bw_in,
        compute_cycles: compute,
        out_stream_cycles: out_stream,
        store_out_cycles: store,
        stall_instr_cycles: stall_instr,
        stall_data_cycles: stall_data,
        macs_used: g.macs(),
        tiles: invocations as usize,
        peak_macs_per_cycle: cfg.peak_macs_per_cycle() as u64,
    })
}

/// Analytical instruction-traffic totals for a choice: (MINISA bits,
/// micro-instruction bits). Mirrors `estimate`'s counting without scoring;
/// `None` when the choice is infeasible.
pub fn instr_traffic(cfg: &ArchConfig, g: &Gemm, choice: &MappingChoice) -> Option<(u64, u64)> {
    let (ms, ks, ns) = search_dims(g, choice.df);
    let vn = choice.vn;
    let mt = choice.m_t.min(ms);
    let kt = choice.k_t.min(ks);
    let nt = choice.n_t.min(ns);
    let kgt = ceil_div(kt, vn);
    let nbt = ceil_div(nt, vn.min(cfg.ah));
    let period = (choice.nbc * choice.dup).min(cfg.aw).max(1);
    let kgc = (cfg.aw / period).max(1);
    let t_steps = ceil_div(mt, choice.dup).max(1) as u64;
    let inv_per_ktile = (ceil_div(nbt, choice.nbc) * ceil_div(kgt, kgc)) as u64;
    let n_tiles =
        (ceil_div(ms, choice.m_t) * ceil_div(ks, choice.k_t) * ceil_div(ns, choice.n_t)) as u64;
    let n_out_tiles = (ceil_div(ms, choice.m_t) * ceil_div(ns, choice.n_t)) as u64;
    let invocations = inv_per_ktile * n_tiles;
    let waves = invocations * t_steps;
    let bw = IsaBitwidths::for_config(cfg);
    let minisa = invocations * (bw.execute_mapping() + bw.execute_streaming()) as u64
        + n_tiles * (2 * bw.load_store() + 2 * bw.set_layout()) as u64
        + n_out_tiles * (bw.set_layout() + bw.load_store()) as u64;
    let mc = crate::microinst::cost(cfg, vn);
    let micro = waves * mc.bits_per_wave + invocations * mc.bits_per_invocation;
    Some((minisa, micro))
}

fn pow2_upto(limit: usize, base: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut x = base.max(1);
    while x < limit {
        v.push(x);
        x *= 2;
    }
    v.push(limit.max(1));
    v.dedup();
    v
}

/// Enumerate mapping candidates (pre-layout) per Tab. VII.
pub fn candidates(cfg: &ArchConfig, g: &Gemm, opts: &MapperOptions) -> Vec<MappingChoice> {
    let dataflows: Vec<Dataflow> = if opts.both_dataflows {
        vec![Dataflow::WoS, Dataflow::IoS]
    } else {
        // §III-C heuristic: IO-S when M > N, else WO-S.
        vec![if g.m > g.n { Dataflow::IoS } else { Dataflow::WoS }]
    };
    candidates_for_dataflows(cfg, g, &dataflows)
}

/// Tab. VII enumeration restricted to the given dataflows (one per chain
/// constraint, both for the free search — avoids enumerating a dataflow's
/// candidates only to discard them).
fn candidates_for_dataflows(cfg: &ArchConfig, g: &Gemm, dataflows: &[Dataflow]) -> Vec<MappingChoice> {
    let mut out = Vec::new();
    for &df in dataflows {
        let (ms, ks, ns) = search_dims(g, df);
        let vn = cfg.ah.min(ks).max(1);
        // Tile extents (Tab. VII): pow2 ladders capped by buffer capacity.
        let max_mt = (cfg.d_str() / vn.max(1)) * cfg.aw; // VN capacity bound
        let m_ts = pow2_upto(ms.min(max_mt.max(cfg.ah)), cfg.ah);
        let k_ts = pow2_upto(ks, vn);
        let n_ts = pow2_upto(ns, 1);
        // Full pow2 ladders for M_t / N_t: capacity feasibility (streaming
        // buffer vs OB) can bind at either end, so pruning to the largest
        // tiles silently loses all feasible candidates for big-M shapes.
        for &m_t in m_ts.iter().rev() {
            for &k_t in k_ts.iter().rev().take(3) {
                for &n_t in n_ts.iter().rev() {
                    // Equivalence pruning (SPerf): nbc beyond the tile's
                    // nb-block count and dup beyond the streamed extent
                    // generate identical invocation structures.
                    let nb_cap = ceil_div(n_t, vn).next_power_of_two().min(cfg.aw);
                    let dup_cap = m_t.next_power_of_two().min(cfg.aw);
                    for nbc in pow2_upto(nb_cap, 1) {
                        for dup in pow2_upto(dup_cap.min(cfg.aw / nbc.max(1)), 1) {
                            if nbc * dup > cfg.aw {
                                continue;
                            }
                            out.push(MappingChoice { df, vn, m_t, k_t, n_t, nbc, dup });
                        }
                    }
                }
            }
        }
    }
    out
}

/// Full mapping-first / layout-second search. Returns the best decision.
pub fn search(cfg: &ArchConfig, g: &Gemm, opts: &MapperOptions) -> Option<Decision> {
    search_constrained(cfg, g, opts, None)
}

/// `search` with an optional dataflow constraint. Chain compilation
/// (`crate::program`) maps each layer under both dataflows and picks the
/// alternating assignment that satisfies the §V-A inter-layer layout
/// compatibility rule; `df = None` reproduces the unconstrained search.
pub fn search_constrained(
    cfg: &ArchConfig,
    g: &Gemm,
    opts: &MapperOptions,
    df: Option<Dataflow>,
) -> Option<Decision> {
    SEARCHES.with(|c| c.set(c.get() + 1));
    // A constraint overrides the M/N-heuristic restriction the caller's
    // options might impose: enumerate exactly the requested dataflow.
    let cands = match df {
        Some(df) => candidates_for_dataflows(cfg, g, &[df]),
        None => candidates(cfg, g, opts),
    };
    // Phase 1 (mapping-first): score every candidate with a fixed good
    // layout pair; parallel across threads. `sort_by` is stable and the
    // scored vector preserves candidate enumeration order, so ties resolve
    // deterministically regardless of thread count.
    let scored = score_parallel(cfg, g, &cands, opts, 4, 0);
    let mut best: Vec<(f64, MappingChoice)> = scored;
    best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    best.truncate(FINALISTS);
    if best.is_empty() {
        return None;
    }
    // Phase 2 (layout-second): refine the finalists over Tab. III orders.
    let orders: Vec<(u8, u8)> = if opts.full_layout_search {
        (0..6u8).flat_map(|i| (0..6u8).map(move |o| (i, o))).collect()
    } else {
        vec![(4, 0)]
    };
    if opts.refine_serial {
        refine_serial(cfg, g, &best, &orders, opts)
    } else {
        refine_parallel(cfg, g, &best, &orders, opts)
    }
}

/// Seed phase-2: serial full-`estimate` sweep over finalists × orders.
/// Kept verbatim as the reference for the parallel refinement's
/// determinism tests and the before/after benchmark.
fn refine_serial(
    cfg: &ArchConfig,
    g: &Gemm,
    finalists: &[(f64, MappingChoice)],
    orders: &[(u8, u8)],
    opts: &MapperOptions,
) -> Option<Decision> {
    let mut winner: Option<Decision> = None;
    for (_, ch) in finalists {
        for &(io, oo) in orders {
            if let Some(rep) = estimate(cfg, g, ch, io, oo, opts.minisa) {
                let better = winner
                    .as_ref()
                    .map(|w| rep.total_cycles < w.report.total_cycles)
                    .unwrap_or(true);
                if better {
                    winner = Some(Decision {
                        choice: *ch,
                        i_order: io,
                        w_order: 0,
                        o_order: oo,
                        report: rep,
                    });
                }
            }
        }
    }
    winner
}

/// Next representable `f64` above a positive finite value; `INFINITY` maps
/// to itself. Used to turn `estimate_bounded`'s `lb >= bound` prune test
/// into a *strict* `lb > incumbent`, which is what makes parallel pruning
/// deterministic: any (finalist, order) whose true cost ties the global
/// minimum has `lb <= min <= incumbent` and therefore always survives, so
/// the deterministic (cost, finalist, order) reduction sees every minimum
/// achiever no matter how threads interleave incumbent updates.
fn next_up(x: f64) -> f64 {
    if x.is_infinite() {
        x
    } else {
        f64::from_bits(x.to_bits() + 1)
    }
}

/// Parallel phase-2 layout refinement (§Perf): finalists are scored across
/// worker threads with `estimate_bounded` against a *shared* incumbent
/// (lock-free `AtomicU64` over the cost's bit pattern — totals are positive,
/// so bit order equals numeric order), instead of the seed's serial
/// 16 × 36 full-`estimate` sweep. The winner is reduced deterministically
/// by (cost, finalist index, order index).
fn refine_parallel(
    cfg: &ArchConfig,
    g: &Gemm,
    finalists: &[(f64, MappingChoice)],
    orders: &[(u8, u8)],
    opts: &MapperOptions,
) -> Option<Decision> {
    use std::sync::atomic::{AtomicU64, Ordering};
    let incumbent = AtomicU64::new(f64::INFINITY.to_bits());
    let threads = opts.threads.max(1).min(finalists.len().max(1));
    let chunk = ceil_div(finalists.len().max(1), threads).max(1);
    let minisa = opts.minisa;
    let per_thread: Vec<Option<(f64, usize, usize, Decision)>> = std::thread::scope(|s| {
        let incumbent = &incumbent;
        let mut handles = Vec::new();
        for (ci, part) in finalists.chunks(chunk).enumerate() {
            handles.push(s.spawn(move || {
                let mut best: Option<(f64, usize, usize, Decision)> = None;
                for (fi, (_, ch)) in part.iter().enumerate() {
                    let fidx = ci * chunk + fi;
                    for (oi, &(io, oo)) in orders.iter().enumerate() {
                        let bound =
                            next_up(f64::from_bits(incumbent.load(Ordering::Relaxed)));
                        let Some(rep) =
                            estimate_bounded(cfg, g, ch, io, oo, minisa, bound)
                        else {
                            continue;
                        };
                        let t = rep.total_cycles;
                        incumbent.fetch_min(t.to_bits(), Ordering::Relaxed);
                        let better = best
                            .as_ref()
                            .map(|b| (t, fidx, oi) < (b.0, b.1, b.2))
                            .unwrap_or(true);
                        if better {
                            best = Some((
                                t,
                                fidx,
                                oi,
                                Decision {
                                    choice: *ch,
                                    i_order: io,
                                    w_order: 0,
                                    o_order: oo,
                                    report: rep,
                                },
                            ));
                        }
                    }
                }
                best
            }));
        }
        handles.into_iter().map(|h| h.join().expect("refiner panicked")).collect()
    });
    let mut winner: Option<(f64, usize, usize, Decision)> = None;
    for r in per_thread.into_iter().flatten() {
        let better =
            winner.as_ref().map(|w| (r.0, r.1, r.2) < (w.0, w.1, w.2)).unwrap_or(true);
        if better {
            winner = Some(r);
        }
    }
    winner.map(|w| w.3)
}

fn score_parallel(
    cfg: &ArchConfig,
    g: &Gemm,
    cands: &[MappingChoice],
    opts: &MapperOptions,
    i_order: u8,
    o_order: u8,
) -> Vec<(f64, MappingChoice)> {
    let threads = opts.threads.max(1).min(cands.len().max(1));
    let chunk = ceil_div(cands.len().max(1), threads);
    let prune = opts.phase1_prune;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for part in cands.chunks(chunk.max(1)) {
            let cfg = cfg.clone();
            let g = g.clone();
            let minisa = opts.minisa;
            handles.push(s.spawn(move || {
                // Thread-local top-FINALISTS scores for branch-and-bound
                // pruning: the bound is PHASE1_BOUND_SLACK × the 16th-best
                // score, which provably cannot evict a pool member (see the
                // PHASE1_BOUND_SLACK docs).
                let mut top: Vec<f64> = Vec::with_capacity(FINALISTS + 1);
                let mut out: Vec<(f64, MappingChoice)> = Vec::new();
                for ch in part {
                    let bound = if prune && top.len() == FINALISTS {
                        top[FINALISTS - 1] * PHASE1_BOUND_SLACK
                    } else {
                        f64::INFINITY
                    };
                    if let Some(r) =
                        estimate_bounded(&cfg, &g, ch, i_order, o_order, minisa, bound)
                    {
                        let t = r.total_cycles;
                        let at = top.partition_point(|&x| x <= t);
                        if at < FINALISTS {
                            top.insert(at, t);
                            top.truncate(FINALISTS);
                        }
                        out.push((t, *ch));
                    }
                }
                out
            }));
        }
        handles.into_iter().flat_map(|h| h.join().expect("scorer panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_finds_feasible_decision() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 64, 40, 24);
        let d = search(&cfg, &g, &MapperOptions::default()).expect("feasible");
        assert!(d.report.total_cycles > 0.0);
        assert!(d.choice.vn <= cfg.ah);
        assert!(d.choice.period() <= cfg.aw);
    }

    #[test]
    fn search_covers_both_dataflows_when_asked() {
        let cfg = ArchConfig::paper(4, 16);
        // Tall-skinny: IO-S (transposed) should win or at least be explored.
        let g = Gemm::new("t", "test", 4096, 64, 8);
        let both = search(&cfg, &g, &MapperOptions::default()).unwrap();
        let single = search(
            &cfg,
            &g,
            &MapperOptions { both_dataflows: false, ..Default::default() },
        )
        .unwrap();
        assert!(both.report.total_cycles <= single.report.total_cycles * 1.001);
    }

    #[test]
    fn estimate_rejects_oversized_tiles() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 1 << 22, 1 << 12, 1 << 12);
        let ch = MappingChoice {
            df: Dataflow::WoS,
            vn: 4,
            m_t: 1 << 22,
            k_t: 1 << 12,
            n_t: 1 << 12,
            nbc: 1,
            dup: 1,
        };
        assert!(estimate(&cfg, &g, &ch, 0, 0, true).is_none());
    }

    #[test]
    fn minisa_estimate_faster_than_micro_at_scale() {
        let cfg = ArchConfig::paper(16, 256);
        let g = Gemm::new("t", "test", 65536, 40, 88);
        let mini = search(&cfg, &g, &MapperOptions::default()).unwrap();
        let micro = estimate(&cfg, &g, &mini.choice, mini.i_order, mini.o_order, false).unwrap();
        let speedup = micro.total_cycles / mini.report.total_cycles;
        // Fig. 10: up to ~31.6× at 16×256.
        assert!(speedup > 5.0, "speedup {speedup}");
        assert!(micro.instr_stall_fraction() > 0.8, "{}", micro.instr_stall_fraction());
        assert!(mini.report.instr_stall_fraction() < 0.05);
    }

    #[test]
    fn utilization_reasonable_for_aligned_workload() {
        let cfg = ArchConfig::paper(4, 16);
        let g = Gemm::new("t", "test", 1024, 64, 64);
        let d = search(&cfg, &g, &MapperOptions::default()).unwrap();
        assert!(d.report.utilization() > 0.5, "util {}", d.report.utilization());
    }

    #[test]
    fn candidate_enumeration_nonempty_for_suite() {
        let cfg = ArchConfig::paper(8, 32);
        for g in crate::workloads::suite_small() {
            let c = candidates(&cfg, &g, &MapperOptions::default());
            assert!(!c.is_empty(), "{g}");
        }
    }

    #[test]
    fn single_thread_matches_parallel() {
        let cfg = ArchConfig::paper(4, 8);
        let g = Gemm::new("t", "test", 256, 40, 24);
        let a = search(&cfg, &g, &MapperOptions { threads: 1, ..Default::default() }).unwrap();
        let b = search(&cfg, &g, &MapperOptions { threads: 8, ..Default::default() }).unwrap();
        assert_eq!(a.report.total_cycles, b.report.total_cycles);
        assert_eq!(a.choice, b.choice);
        // The parallel phase-2 refinement must also pick identical layouts.
        assert_eq!((a.i_order, a.w_order, a.o_order), (b.i_order, b.w_order, b.o_order));
    }

    /// Parallel bounded phase-2 refinement is a pure optimization: it picks
    /// the same (choice, orders, cost) as the seed's serial full-`estimate`
    /// sweep, at any thread count.
    #[test]
    fn parallel_refinement_matches_serial_reference() {
        for (ah, aw, m, k, n) in
            [(4usize, 8usize, 256usize, 40usize, 24usize), (4, 16, 64, 40, 88), (8, 8, 96, 33, 17)]
        {
            let cfg = ArchConfig::paper(ah, aw);
            let g = Gemm::new("t", "test", m, k, n);
            let serial = search(
                &cfg,
                &g,
                &MapperOptions { refine_serial: true, threads: 1, ..Default::default() },
            )
            .unwrap();
            for threads in [1usize, 4, 16] {
                let par =
                    search(&cfg, &g, &MapperOptions { threads, ..Default::default() }).unwrap();
                assert_eq!(par.report.total_cycles, serial.report.total_cycles, "{g} t{threads}");
                assert_eq!(par.choice, serial.choice, "{g} t{threads}");
                assert_eq!(
                    (par.i_order, par.w_order, par.o_order),
                    (serial.i_order, serial.w_order, serial.o_order),
                    "{g} t{threads}"
                );
            }
        }
    }

    /// Branch-and-bound pruning (phase-1 slack bound and the phase-2 shared
    /// incumbent) never changes the selected winner relative to an
    /// exhaustive unpruned search.
    #[test]
    fn pruning_never_changes_winner() {
        for (m, k, n) in [(64usize, 40usize, 24usize), (512, 64, 8), (96, 33, 17)] {
            let cfg = ArchConfig::paper(4, 8);
            let g = Gemm::new("t", "test", m, k, n);
            let pruned = search(&cfg, &g, &MapperOptions::default()).unwrap();
            let exhaustive = search(
                &cfg,
                &g,
                &MapperOptions {
                    phase1_prune: false,
                    refine_serial: true,
                    threads: 1,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(pruned.report.total_cycles, exhaustive.report.total_cycles, "({m},{k},{n})");
            assert_eq!(pruned.choice, exhaustive.choice, "({m},{k},{n})");
        }
    }
}
