//! Steps 2–6: candidate enumeration, feasibility checks and latency-driven
//! selection (§V-B, Tab. VII), parallelized across worker threads.
//!
//! The mapping space is parameterized by three knobs (tile size, VN-group
//! formation `nbc`, duplication `dup`) plus the dataflow bit; layouts are
//! then searched over Tab. III orders for the streamed and output tensors.
//! Candidates that violate buffer capacity are discarded (step 6a);
//! streaming-row-block and OB-pressure serialization enter the latency
//! estimate rather than hard rejection (FEATHER+'s crossbar makes them
//! legal-but-slower, §V-B6b/c).

use super::lower::{
    ob_pressure_factor, output_layout, search_dims, stationary_layout, streamed_layout,
};
use super::{Decision, MappingChoice};
use crate::arch::config::ArchConfig;
use crate::isa::bitwidth::IsaBitwidths;
use crate::mapping::{Dataflow, MappingCfg, StreamCfg};
use crate::perf::PerfReport;
use crate::util::ceil_div;
use crate::workloads::Gemm;

/// Search configuration.
#[derive(Debug, Clone)]
pub struct MapperOptions {
    /// Search both dataflows (default) or only the M/N heuristic's pick.
    pub both_dataflows: bool,
    /// Search all 6×6 streamed/output order pairs for the finalists
    /// (otherwise a fixed good pair).
    pub full_layout_search: bool,
    /// Worker threads for candidate scoring.
    pub threads: usize,
    /// Instruction mode for the latency estimate: MINISA (true) or the
    /// micro-instruction baseline (false) — used for Fig. 10 comparisons.
    pub minisa: bool,
}

impl Default for MapperOptions {
    fn default() -> Self {
        Self { both_dataflows: true, full_layout_search: true, threads: 4, minisa: true }
    }
}

/// Closed-form pipeline estimate for one candidate (steady-state bound of
/// the engine pipeline in `perf::simulate`; exact for uniform tiles).
pub fn estimate(
    cfg: &ArchConfig,
    g: &Gemm,
    choice: &MappingChoice,
    i_order: u8,
    o_order: u8,
    minisa: bool,
) -> Option<PerfReport> {
    estimate_bounded(cfg, g, choice, i_order, o_order, minisa, f64::INFINITY)
}

/// `estimate` with branch-and-bound pruning: returns `None` early when the
/// probe-free lower bound (serialization factors only *increase* latency)
/// already exceeds `bound` (§Perf optimization).
#[allow(clippy::too_many_arguments)]
pub fn estimate_bounded(
    cfg: &ArchConfig,
    g: &Gemm,
    choice: &MappingChoice,
    i_order: u8,
    o_order: u8,
    minisa: bool,
    bound: f64,
) -> Option<PerfReport> {
    let (ms, ks, ns) = search_dims(g, choice.df);
    let vn = choice.vn;
    let ah = cfg.ah;
    let aw = cfg.aw;
    if vn > ah || choice.m_t == 0 || choice.k_t == 0 || choice.n_t == 0 {
        return None;
    }
    let mt = choice.m_t.min(ms);
    let kt = choice.k_t.min(ks);
    let nt = choice.n_t.min(ns);
    let kgt = ceil_div(kt, vn);
    let rows_active = vn.min(ah);
    let nbt = ceil_div(nt, rows_active);
    // Capacity feasibility (step 6a).
    let i_lay = streamed_layout(choice, mt, kgt, i_order);
    let w_lay = stationary_layout(cfg, choice, nt, kgt, 0);
    let (p_ext, q_ext) = match choice.df {
        Dataflow::WoS => (mt, nt),
        Dataflow::IoS => (nt, mt),
    };
    let o_lay = output_layout(cfg, choice, p_ext, q_ext, o_order);
    if !i_lay.fits(cfg.d_str(), aw) || !w_lay.fits(cfg.d_sta(), aw) || !o_lay.fits(cfg.d_ob(), aw)
    {
        return None;
    }
    // Interior-tile invocation structure.
    let period = (choice.nbc * choice.dup).min(aw).max(1);
    let kgc = (aw / period).max(1);
    let t_steps = ceil_div(mt, choice.dup).max(1) as u64;
    let inv_per_ktile = (ceil_div(nbt, choice.nbc) * ceil_div(kgt, kgc)) as u64;
    let n_tiles =
        (ceil_div(ms, choice.m_t) * ceil_div(ks, choice.k_t) * ceil_div(ns, choice.n_t)) as u64;
    let n_out_tiles = (ceil_div(ms, choice.m_t) * ceil_div(ns, choice.n_t)) as u64;
    let invocations = inv_per_ktile * n_tiles;
    let waves = invocations * t_steps;

    // Probe-free lower bound: factor >= 1, so compute-only + fixed engine
    // totals bound the final latency from below. Prune before the (more
    // expensive) per-wave probes when it cannot beat `bound`.
    let compute_lb = (waves * vn as u64) as f64 + (invocations * cfg.drain_cycles() as u64) as f64;
    if compute_lb >= bound {
        return None;
    }

    // Serialization factors probed on the interior tile.
    let em = MappingCfg { r0: 0, c0: 0, g_r: period, g_c: choice.nbc, s_r: 1, s_c: rows_active };
    let es = StreamCfg {
        df: choice.df,
        m0: 0,
        s_m: choice.dup,
        t: t_steps as usize,
        vn_size: vn,
    };
    let sf = super::lower::stream_block_factor(cfg, choice, &i_lay, &em, &es);
    let of = ob_pressure_factor(cfg, choice, &o_lay, &em, &es, p_ext, q_ext);
    let factor = sf.max(of) as u64;

    // Engine totals.
    let bw = IsaBitwidths::for_config(cfg);
    let instr_bits = if minisa {
        invocations * (bw.execute_mapping() + bw.execute_streaming()) as u64
            + n_tiles * (2 * bw.load_store() + 2 * bw.set_layout()) as u64
            + n_out_tiles * (bw.set_layout() + bw.load_store()) as u64
    } else {
        let mc = crate::microinst::cost(cfg, vn);
        waves * mc.bits_per_wave + invocations * mc.bits_per_invocation
    };
    let fetch = instr_bits as f64 / (cfg.instr_bw * 8.0);
    let load_in_words = (ms * ks) as f64 * ceil_div(ns, choice.n_t) as f64; // streamed reloaded per n-tile
    let load_w_words = (ks * ns) as f64 * ceil_div(ms, choice.m_t) as f64;
    let load = (load_in_words + load_w_words) * cfg.elem_bytes as f64 / cfg.data_bw_in;
    let compute = (waves * vn as u64 * factor) as f64
        + (invocations * cfg.drain_cycles() as u64) as f64;
    let out_words = (ms * ns) as f64;
    let out_stream = out_words / aw as f64;
    let store = out_words * cfg.acc_bytes as f64 / cfg.data_bw_out;

    let total = fetch.max(load).max(compute).max(out_stream).max(store);
    let stall_instr = (fetch - load.max(compute).max(store)).max(0.0);
    let stall_data = (load - compute.max(fetch).max(store)).max(0.0);
    Some(PerfReport {
        total_cycles: total,
        fetch_cycles: fetch,
        load_in_cycles: load_in_words * cfg.elem_bytes as f64 / cfg.data_bw_in,
        load_w_cycles: load_w_words * cfg.elem_bytes as f64 / cfg.data_bw_in,
        compute_cycles: compute,
        out_stream_cycles: out_stream,
        store_out_cycles: store,
        stall_instr_cycles: stall_instr,
        stall_data_cycles: stall_data,
        macs_used: g.macs(),
        tiles: invocations as usize,
        peak_macs_per_cycle: cfg.peak_macs_per_cycle() as u64,
    })
}

/// Analytical instruction-traffic totals for a choice: (MINISA bits,
/// micro-instruction bits). Mirrors `estimate`'s counting without scoring;
/// `None` when the choice is infeasible.
pub fn instr_traffic(cfg: &ArchConfig, g: &Gemm, choice: &MappingChoice) -> Option<(u64, u64)> {
    let (ms, ks, ns) = search_dims(g, choice.df);
    let vn = choice.vn;
    let mt = choice.m_t.min(ms);
    let kt = choice.k_t.min(ks);
    let nt = choice.n_t.min(ns);
    let kgt = ceil_div(kt, vn);
    let nbt = ceil_div(nt, vn.min(cfg.ah));
    let period = (choice.nbc * choice.dup).min(cfg.aw).max(1);
    let kgc = (cfg.aw / period).max(1);
    let t_steps = ceil_div(mt, choice.dup).max(1) as u64;
    let inv_per_ktile = (ceil_div(nbt, choice.nbc) * ceil_div(kgt, kgc)) as u64;
    let n_tiles =
        (ceil_div(ms, choice.m_t) * ceil_div(ks, choice.k_t) * ceil_div(ns, choice.n_t)) as u64;
    let n_out_tiles = (ceil_div(ms, choice.m_t) * ceil_div(ns, choice.n_t)) as u64;
    let invocations = inv_per_ktile * n_tiles;
    let waves = invocations * t_steps;
    let bw = IsaBitwidths::for_config(cfg);
    let minisa = invocations * (bw.execute_mapping() + bw.execute_streaming()) as u64
        + n_tiles * (2 * bw.load_store() + 2 * bw.set_layout()) as u64
        + n_out_tiles * (bw.set_layout() + bw.load_store()) as u64;
    let mc = crate::microinst::cost(cfg, vn);
    let micro = waves * mc.bits_per_wave + invocations * mc.bits_per_invocation;
    Some((minisa, micro))
}

fn pow2_upto(limit: usize, base: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut x = base.max(1);
    while x < limit {
        v.push(x);
        x *= 2;
    }
    v.push(limit.max(1));
    v.dedup();
    v
}

/// Enumerate mapping candidates (pre-layout) per Tab. VII.
pub fn candidates(cfg: &ArchConfig, g: &Gemm, opts: &MapperOptions) -> Vec<MappingChoice> {
    let mut out = Vec::new();
    let dataflows: Vec<Dataflow> = if opts.both_dataflows {
        vec![Dataflow::WoS, Dataflow::IoS]
    } else {
        // §III-C heuristic: IO-S when M > N, else WO-S.
        vec![if g.m > g.n { Dataflow::IoS } else { Dataflow::WoS }]
    };
    for df in dataflows {
        let (ms, ks, ns) = search_dims(g, df);
        let vn = cfg.ah.min(ks).max(1);
        // Tile extents (Tab. VII): pow2 ladders capped by buffer capacity.
        let max_mt = (cfg.d_str() / vn.max(1)) * cfg.aw; // VN capacity bound
        let m_ts = pow2_upto(ms.min(max_mt.max(cfg.ah)), cfg.ah);
        let k_ts = pow2_upto(ks, vn);
        let n_ts = pow2_upto(ns, 1);
        // Full pow2 ladders for M_t / N_t: capacity feasibility (streaming
        // buffer vs OB) can bind at either end, so pruning to the largest
        // tiles silently loses all feasible candidates for big-M shapes.
        for &m_t in m_ts.iter().rev() {
            for &k_t in k_ts.iter().rev().take(3) {
                for &n_t in n_ts.iter().rev() {
                    // Equivalence pruning (SPerf): nbc beyond the tile's
                    // nb-block count and dup beyond the streamed extent
                    // generate identical invocation structures.
                    let nb_cap = ceil_div(n_t, vn).next_power_of_two().min(cfg.aw);
                    let dup_cap = m_t.next_power_of_two().min(cfg.aw);
                    for nbc in pow2_upto(nb_cap, 1) {
                        for dup in pow2_upto(dup_cap.min(cfg.aw / nbc.max(1)), 1) {
                            if nbc * dup > cfg.aw {
                                continue;
                            }
                            out.push(MappingChoice { df, vn, m_t, k_t, n_t, nbc, dup });
                        }
                    }
                }
            }
        }
    }
    out
}

/// Full mapping-first / layout-second search. Returns the best decision.
pub fn search(cfg: &ArchConfig, g: &Gemm, opts: &MapperOptions) -> Option<Decision> {
    let cands = candidates(cfg, g, opts);
    // Phase 1 (mapping-first): score every candidate with a fixed good
    // layout pair; parallel across threads.
    let scored = score_parallel(cfg, g, &cands, opts, 4, 0);
    let mut best: Vec<(f64, MappingChoice)> = scored;
    best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    best.truncate(16);
    if best.is_empty() {
        return None;
    }
    // Phase 2 (layout-second): refine the finalists over Tab. III orders.
    let mut winner: Option<Decision> = None;
    for (_, ch) in &best {
        let orders: Vec<(u8, u8)> = if opts.full_layout_search {
            (0..6u8).flat_map(|i| (0..6u8).map(move |o| (i, o))).collect()
        } else {
            vec![(4, 0)]
        };
        for (io, oo) in orders {
            if let Some(rep) = estimate(cfg, g, ch, io, oo, opts.minisa) {
                let better = winner
                    .as_ref()
                    .map(|w| rep.total_cycles < w.report.total_cycles)
                    .unwrap_or(true);
                if better {
                    winner = Some(Decision {
                        choice: *ch,
                        i_order: io,
                        w_order: 0,
                        o_order: oo,
                        report: rep,
                    });
                }
            }
        }
    }
    winner
}

fn score_parallel(
    cfg: &ArchConfig,
    g: &Gemm,
    cands: &[MappingChoice],
    opts: &MapperOptions,
    i_order: u8,
    o_order: u8,
) -> Vec<(f64, MappingChoice)> {
    let threads = opts.threads.max(1).min(cands.len().max(1));
    let chunk = ceil_div(cands.len().max(1), threads);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for part in cands.chunks(chunk.max(1)) {
            let cfg = cfg.clone();
            let g = g.clone();
            let minisa = opts.minisa;
            handles.push(s.spawn(move || {
                // Thread-local incumbent for branch-and-bound pruning.
                let mut best = f64::INFINITY;
                let mut out: Vec<(f64, MappingChoice)> = Vec::new();
                for ch in part {
                    if let Some(r) =
                        estimate_bounded(&cfg, &g, ch, i_order, o_order, minisa, best * 4.0)
                    {
                        best = best.min(r.total_cycles);
                        out.push((r.total_cycles, *ch));
                    }
                }
                out
            }));
        }
        handles.into_iter().flat_map(|h| h.join().expect("scorer panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_finds_feasible_decision() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 64, 40, 24);
        let d = search(&cfg, &g, &MapperOptions::default()).expect("feasible");
        assert!(d.report.total_cycles > 0.0);
        assert!(d.choice.vn <= cfg.ah);
        assert!(d.choice.period() <= cfg.aw);
    }

    #[test]
    fn search_covers_both_dataflows_when_asked() {
        let cfg = ArchConfig::paper(4, 16);
        // Tall-skinny: IO-S (transposed) should win or at least be explored.
        let g = Gemm::new("t", "test", 4096, 64, 8);
        let both = search(&cfg, &g, &MapperOptions::default()).unwrap();
        let single = search(
            &cfg,
            &g,
            &MapperOptions { both_dataflows: false, ..Default::default() },
        )
        .unwrap();
        assert!(both.report.total_cycles <= single.report.total_cycles * 1.001);
    }

    #[test]
    fn estimate_rejects_oversized_tiles() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 1 << 22, 1 << 12, 1 << 12);
        let ch = MappingChoice {
            df: Dataflow::WoS,
            vn: 4,
            m_t: 1 << 22,
            k_t: 1 << 12,
            n_t: 1 << 12,
            nbc: 1,
            dup: 1,
        };
        assert!(estimate(&cfg, &g, &ch, 0, 0, true).is_none());
    }

    #[test]
    fn minisa_estimate_faster_than_micro_at_scale() {
        let cfg = ArchConfig::paper(16, 256);
        let g = Gemm::new("t", "test", 65536, 40, 88);
        let mini = search(&cfg, &g, &MapperOptions::default()).unwrap();
        let micro = estimate(&cfg, &g, &mini.choice, mini.i_order, mini.o_order, false).unwrap();
        let speedup = micro.total_cycles / mini.report.total_cycles;
        // Fig. 10: up to ~31.6× at 16×256.
        assert!(speedup > 5.0, "speedup {speedup}");
        assert!(micro.instr_stall_fraction() > 0.8, "{}", micro.instr_stall_fraction());
        assert!(mini.report.instr_stall_fraction() < 0.05);
    }

    #[test]
    fn utilization_reasonable_for_aligned_workload() {
        let cfg = ArchConfig::paper(4, 16);
        let g = Gemm::new("t", "test", 1024, 64, 64);
        let d = search(&cfg, &g, &MapperOptions::default()).unwrap();
        assert!(d.report.utilization() > 0.5, "util {}", d.report.utilization());
    }

    #[test]
    fn candidate_enumeration_nonempty_for_suite() {
        let cfg = ArchConfig::paper(8, 32);
        for g in crate::workloads::suite_small() {
            let c = candidates(&cfg, &g, &MapperOptions::default());
            assert!(!c.is_empty(), "{g}");
        }
    }

    #[test]
    fn single_thread_matches_parallel() {
        let cfg = ArchConfig::paper(4, 8);
        let g = Gemm::new("t", "test", 256, 40, 24);
        let a = search(&cfg, &g, &MapperOptions { threads: 1, ..Default::default() }).unwrap();
        let b = search(&cfg, &g, &MapperOptions { threads: 8, ..Default::default() }).unwrap();
        assert_eq!(a.report.total_cycles, b.report.total_cycles);
        assert_eq!(a.choice, b.choice);
    }
}
