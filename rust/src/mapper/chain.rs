//! Multi-layer (chain/DAG) mapping — the ACT-integration role of the
//! FEATHER+ Mapper (§V-A, §V-B7): "for multi-layer workloads, the mapper
//! additionally enforces inter-layer layout compatibility: the output
//! layout of layer i must match the input layout expected by layer i+1; it
//! then searches over all surviving cross-layer combinations and selects
//! the choice with minimum overall latency."
//!
//! Layers alternate dataflow naturally (a WO-S layer commits its outputs to
//! the stationary buffer through the OB→StaB link, feeding an IO-S
//! successor, and vice versa — §III-B refinement 3), and every interior
//! `SetIVNLayout` that matches its predecessor's `SetOVNLayout` is elided
//! from the fused trace (§IV-G2).

use super::search::MapperOptions;
use super::Decision;
use crate::arch::config::ArchConfig;
use crate::mapping::Dataflow;
use crate::workloads::Gemm;

/// A linear chain of GEMM layers: layer i's M×N output is layer i+1's M×K
/// input (so `layers[i].n == layers[i+1].k` and M is shared).
#[derive(Debug, Clone, PartialEq)]
pub struct Chain {
    pub layers: Vec<Gemm>,
}

impl Chain {
    /// Build a chain from (K, N) pairs at a fixed M (e.g. an MLP).
    pub fn mlp(name: &str, m: usize, dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| Gemm::new(&format!("{name}_l{i}"), "chain", m, w[0], w[1]))
            .collect();
        Self { layers }
    }

    pub fn validate(&self) -> Result<(), String> {
        for (i, w) in self.layers.windows(2).enumerate() {
            if w[0].n != w[1].k {
                return Err(format!("layer {i} N={} != layer {} K={}", w[0].n, i + 1, w[1].k));
            }
            if w[0].m != w[1].m {
                return Err(format!("layer {i} M mismatch"));
            }
        }
        Ok(())
    }
}

/// A chain mapping: one decision per layer + the fused trace statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainDecision {
    pub per_layer: Vec<Decision>,
    /// Total modeled cycles (sum of layer latencies; layers are serialized
    /// by the data dependence).
    pub total_cycles: f64,
    /// SetIVNLayout instructions elided at layer boundaries (§IV-G2).
    pub elided: usize,
    /// Fused trace size in bytes, after elision.
    pub fused_bytes: u64,
    /// Sum of standalone per-layer trace bytes (no elision), for reporting.
    pub standalone_bytes: u64,
}

/// Compatibility: layer i's output VNs become layer i+1's input VNs, so the
/// successor's streamed-layout *order and factors* must equal the
/// predecessor's output layout (we compare the layout descriptors the two
/// traces would program).
pub(crate) fn boundary_compatible(
    prev: &Decision,
    next: &Decision,
    cfg: &ArchConfig,
    gs: (&Gemm, &Gemm),
) -> bool {
    let (g_prev, g_next) = gs;
    // The committed output tile of `prev` must cover what `next` streams in
    // one tile, with identical VN size and order.
    let prev_choice = prev.choice;
    let next_choice = next.choice;
    if prev_choice.vn != next_choice.vn {
        return false;
    }
    // Dataflow alternation through the OB→StaB/StrB link (§III-B): the
    // next layer must *consume* from the buffer the previous layer commits
    // to. WO-S commits stationary (→ next is IO-S); IO-S commits streaming
    // (→ next is WO-S).
    let expected_next = match prev_choice.df {
        Dataflow::WoS => Dataflow::IoS,
        Dataflow::IoS => Dataflow::WoS,
    };
    if next_choice.df != expected_next {
        return false;
    }
    // Output layout of prev vs consumed layout of next: compare the
    // descriptors (order + partition factors over matching extents).
    let (p_ext, q_ext) = match prev_choice.df {
        Dataflow::WoS => (prev_choice.m_t.min(g_prev.m), prev_choice.n_t.min(g_prev.n)),
        Dataflow::IoS => (prev_choice.n_t.min(g_prev.m), prev_choice.m_t.min(g_prev.n)),
    };
    let o_lay = super::lower::output_layout(cfg, &prev_choice, p_ext, q_ext, prev.o_order);
    let (ms, ks, _) = super::lower::search_dims(g_next, next_choice.df);
    let kgt = crate::util::ceil_div(next_choice.k_t.min(ks), next_choice.vn);
    let consumed = match next_choice.df {
        // Next streams its input.
        Dataflow::WoS => super::lower::streamed_layout(
            &next_choice,
            next_choice.m_t.min(ms),
            kgt,
            next.i_order,
        ),
        // Next keeps its input stationary.
        Dataflow::IoS => super::lower::stationary_layout(
            cfg,
            &next_choice,
            next_choice.n_t.min(super::lower::search_dims(g_next, next_choice.df).2),
            kgt,
            next.w_order,
        ),
    };
    o_lay.order == consumed.order && o_lay.vn_size == consumed.vn_size
}

/// Map a chain: the chain-aware mapper pass of [`crate::program`] — each
/// layer searched under both dataflows, the cheaper §V-A alternating
/// assignment selected, boundary layout orders aligned; layers whose
/// required dataflow is infeasible fall back to an explicit re-layout (no
/// elision at that boundary). This is a reporting view over
/// [`crate::program::Program::compile`]; serve-path callers should compile
/// (and keep) the full `Program` instead.
pub fn map_chain(cfg: &ArchConfig, chain: &Chain, opts: &MapperOptions) -> Option<ChainDecision> {
    Some(crate::program::Program::compile(cfg, chain, opts)?.chain_decision())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> MapperOptions {
        MapperOptions { full_layout_search: false, ..Default::default() }
    }

    #[test]
    fn mlp_chain_builds_and_validates() {
        let c = Chain::mlp("mlp", 64, &[128, 256, 64]);
        assert_eq!(c.layers.len(), 2);
        c.validate().unwrap();
        assert_eq!(c.layers[0].n, c.layers[1].k);
    }

    #[test]
    fn mismatched_chain_rejected() {
        let c = Chain {
            layers: vec![
                Gemm::new("a", "t", 8, 16, 32),
                Gemm::new("b", "t", 8, 64, 8), // K != prev N
            ],
        };
        assert!(c.validate().is_err());
        assert!(map_chain(&ArchConfig::paper(4, 4), &c, &opts()).is_none());
    }

    #[test]
    fn validate_reports_dimension_errors_precisely() {
        // N/K mismatch names both layers and extents.
        let nk = Chain {
            layers: vec![Gemm::new("a", "t", 8, 16, 32), Gemm::new("b", "t", 8, 48, 8)],
        };
        let msg = nk.validate().unwrap_err();
        assert!(msg.contains("N=32") && msg.contains("K=48"), "{msg}");
        // M mismatch is its own error.
        let m = Chain {
            layers: vec![Gemm::new("a", "t", 8, 16, 32), Gemm::new("b", "t", 16, 32, 8)],
        };
        let msg = m.validate().unwrap_err();
        assert!(msg.contains("M mismatch"), "{msg}");
        // Single-layer chains are trivially valid (no boundary).
        Chain { layers: vec![Gemm::new("a", "t", 8, 16, 32)] }.validate().unwrap();
    }

    /// The chain-aware mapper alternates dataflows across layers — the
    /// §III-B buffer hand-off that makes §V-A boundary compatibility (and
    /// with it §IV-G2 elision) possible at all.
    #[test]
    fn chain_dataflows_alternate() {
        let cfg = ArchConfig::paper(4, 4);
        let c = Chain::mlp("mlp", 32, &[32, 32, 32, 32]);
        let d = map_chain(&cfg, &c, &opts()).unwrap();
        assert_eq!(d.per_layer.len(), 3);
        let dfs: Vec<_> = d.per_layer.iter().map(|l| l.choice.df).collect();
        assert!(dfs.windows(2).all(|w| w[0] != w[1]), "alternating dataflows: {dfs:?}");
    }

    /// §IV-G2 on a 3-layer MLP: at least one interior `SetIVNLayout` is
    /// elidable because the predecessor's committed output layout already
    /// describes it.
    #[test]
    fn three_layer_mlp_elides_interlayer_layout() {
        let cfg = ArchConfig::paper(4, 4);
        let c = Chain::mlp("mlp", 32, &[32, 32, 32, 32]);
        let d = map_chain(&cfg, &c, &opts()).unwrap();
        assert!(d.elided >= 1, "elided {}", d.elided);
        assert!(d.fused_bytes <= d.standalone_bytes);
    }

    #[test]
    fn chain_maps_and_accounts_bytes() {
        let cfg = ArchConfig::paper(4, 16);
        let c = Chain::mlp("mlp", 64, &[40, 88, 24]);
        let d = map_chain(&cfg, &c, &opts()).unwrap();
        assert_eq!(d.per_layer.len(), 2);
        assert!(d.total_cycles > 0.0);
        // The fused trace is never bigger than the standalone sum.
        assert!(d.fused_bytes <= d.standalone_bytes, "{} vs {}", d.fused_bytes, d.standalone_bytes);
    }

    #[test]
    fn chain_total_is_sum_of_layers() {
        let cfg = ArchConfig::paper(4, 4);
        let c = Chain::mlp("mlp", 32, &[32, 32, 32, 32]);
        let d = map_chain(&cfg, &c, &opts()).unwrap();
        let sum: f64 = d.per_layer.iter().map(|l| l.report.total_cycles).sum();
        assert_eq!(d.total_cycles, sum);
        assert_eq!(d.per_layer.len(), 3);
    }
}
