//! Execution driver: replay a lowered program through the functional
//! simulator with real operand data, harvesting finished output tiles.
//!
//! This closes the correctness loop: mapper → MINISA trace → functional
//! simulation must reproduce a naive GEMM bit-exactly (and, in integration
//! tests, the PJRT-executed JAX/Pallas oracle).

use super::lower::{LoweredProgram, StagedOperand, Staging};
use crate::arch::config::ArchConfig;
use crate::functional::{pack_image, FunctionalSim, SimError};
use crate::isa::inst::Inst;
use crate::mapping::Dataflow;
use crate::workloads::Gemm;

/// Materialize one staging region's buffer image from the logical operands.
fn stage_image(g: &Gemm, df: Dataflow, s: &Staging, iv: &[i32], wv: &[i32], aw: usize) -> Vec<i32> {
    let vn = s.layout.vn_size;
    // Element accessors with global zero-padding.
    let from_i = |c: usize, r: usize, e: usize| -> i32 {
        // I[m, k] with m = nonred0 + c, k = k0 + r·vn + e.
        let m = s.nonred0 + c;
        let k = s.k0 + r * vn + e;
        if c >= s.nonred_t || m >= g.m || r * vn + e >= s.kt || k >= g.k {
            0
        } else {
            iv[m * g.k + k]
        }
    };
    let from_w = |c: usize, r: usize, e: usize| -> i32 {
        // W[k, n] with n = nonred0 + c, k = k0 + r·vn + e.
        let n = s.nonred0 + c;
        let k = s.k0 + r * vn + e;
        if c >= s.nonred_t || n >= g.n || r * vn + e >= s.kt || k >= g.k {
            0
        } else {
            wv[k * g.n + n]
        }
    };
    // Under WO-S the streamed tensor is I and the stationary is W; under
    // IO-S the roles (and the search-space transposition) swap them.
    let use_input = matches!(
        (df, s.operand),
        (Dataflow::WoS, StagedOperand::Streamed) | (Dataflow::IoS, StagedOperand::Stationary)
    );
    pack_image(&s.layout, aw, |r, c| {
        (0..vn).map(|e| if use_input { from_i(c, r, e) } else { from_w(c, r, e) }).collect()
    })
}

/// Replay a lowered program on real operands; returns the logical `M × N`
/// output (row-major, i64 accumulators).
pub fn execute_program(
    cfg: &ArchConfig,
    g: &Gemm,
    prog: &LoweredProgram,
    iv: &[i32],
    wv: &[i32],
) -> Result<Vec<i64>, SimError> {
    let mut sim = FunctionalSim::new(cfg);
    execute_program_on(&mut sim, g, prog, iv, wv)
}

/// `execute_program` against a caller-provided simulator. Lets callers
/// reuse one simulator (and its compiled [`crate::functional::WavePlan`]
/// cache) across programs, or flip `sim.use_plans` to run the reference
/// interpreter (the plan-equivalence tests do both).
pub fn execute_program_on(
    sim: &mut FunctionalSim,
    g: &Gemm,
    prog: &LoweredProgram,
    iv: &[i32],
    wv: &[i32],
) -> Result<Vec<i64>, SimError> {
    assert_eq!(iv.len(), g.m * g.k, "input operand shape");
    assert_eq!(wv.len(), g.k * g.n, "weight operand shape");
    let aw = sim.cfg.aw;
    for s in &prog.staging {
        let img = stage_image(g, prog.choice.df, s, iv, wv, aw);
        debug_assert_eq!(img.len(), s.words);
        sim.hbm_write(s.hbm_addr, &img);
    }
    let mut out = vec![0i64; g.m * g.n];
    let mut harvested = 0usize;
    for inst in &prog.trace.insts {
        if matches!(inst, Inst::SetOVNLayout(_)) && harvested > 0 {
            harvest(&sim, g, prog, harvested - 1, &mut out)?;
        }
        if matches!(inst, Inst::SetOVNLayout(_)) {
            harvested += 1;
        }
        sim.exec(inst)?;
    }
    if harvested > 0 {
        harvest(&sim, g, prog, harvested - 1, &mut out)?;
    }
    debug_assert_eq!(harvested, prog.harvests.len());
    Ok(out)
}

fn harvest(
    sim: &FunctionalSim,
    g: &Gemm,
    prog: &LoweredProgram,
    idx: usize,
    out: &mut [i64],
) -> Result<(), SimError> {
    let h = &prog.harvests[idx];
    for p in 0..h.p_ext {
        for q in 0..h.q_ext {
            let (m, n) = (h.m0 + p, h.n0 + q);
            if m >= g.m || n >= g.n {
                continue;
            }
            let v = sim
                .output_element(p, q)
                .ok_or(SimError::Invalid(format!("harvest ({p},{q}) unmapped")))?;
            out[m * g.n + n] = v;
        }
    }
    Ok(())
}

/// Convenience: lower + execute + compare against the naive reference.
/// Returns (simulated output, reference output).
pub fn validate_decision(
    cfg: &ArchConfig,
    g: &Gemm,
    prog: &LoweredProgram,
    seed: u64,
) -> Result<(Vec<i64>, Vec<i64>), SimError> {
    let mut rng = crate::util::Lcg::new(seed);
    let iv: Vec<i32> = (0..g.m * g.k).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let wv: Vec<i32> = (0..g.k * g.n).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let got = execute_program(cfg, g, prog, &iv, &wv)?;
    let expect = crate::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
    Ok((got, expect))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::lower::lower_gemm;
    use crate::mapper::MappingChoice;
    use crate::util::prop::forall;

    fn check(cfg: &ArchConfig, g: &Gemm, ch: &MappingChoice, orders: (u8, u8, u8)) {
        let prog = lower_gemm(cfg, g, ch, orders.0, orders.1, orders.2);
        let (got, expect) = validate_decision(cfg, g, &prog, 42).unwrap_or_else(|e| {
            panic!("{} {:?} orders {:?}: {e}", g, ch, orders);
        });
        assert_eq!(got, expect, "{} {:?} orders {:?}", g, ch, orders);
    }

    #[test]
    fn exact_single_tile() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 8, 8, 8);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        check(&cfg, &g, &ch, (0, 0, 0));
    }

    #[test]
    fn multi_tile_all_dims() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 12, 20, 10);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        check(&cfg, &g, &ch, (0, 0, 0));
    }

    #[test]
    fn duplication_and_nbc_variants() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 16, 8, 16);
        for (nbc, dup) in [(1usize, 1usize), (2, 1), (1, 2), (2, 2), (4, 1), (1, 4)] {
            let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 16, k_t: 8, n_t: 16, nbc, dup };
            check(&cfg, &g, &ch, (0, 0, 0));
        }
    }

    #[test]
    fn ios_dataflow_exact() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 6, 8, 12);
        let ch = MappingChoice { df: Dataflow::IoS, vn: 4, m_t: 16, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        check(&cfg, &g, &ch, (0, 0, 0));
    }

    #[test]
    fn all_layout_orders_preserve_semantics() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 8, 12, 8);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 12, n_t: 8, nbc: 2, dup: 2 };
        for io in 0..6u8 {
            for oo in 0..6u8 {
                check(&cfg, &g, &ch, (io, 0, oo));
            }
        }
        for wo in 0..6u8 {
            check(&cfg, &g, &ch, (0, wo, 0));
        }
    }

    #[test]
    fn randomized_mapper_correctness() {
        // The core property of the whole stack: any legal decision lowers
        // to a trace whose functional execution equals the naive GEMM.
        forall("mapper-lowering-exact", 60, |gen| {
            let (ah, aw) = *gen.pick(&[(4usize, 4usize), (4, 8), (8, 8)]);
            let cfg = ArchConfig::paper(ah, aw);
            let m = gen.usize(1, 24);
            let k = gen.usize(1, 24);
            let n = gen.usize(1, 24);
            let g = Gemm::new("p", "prop", m, k, n);
            let vn = ah.min(k).max(1);
            let df = if gen.bool() { Dataflow::WoS } else { Dataflow::IoS };
            let (ms, ks, ns) = crate::mapper::lower::search_dims(&g, df);
            let m_t = gen.pick(&[ah, 2 * ah, 4 * ah]).min(&ms.max(1)).to_owned().max(1);
            let k_t = (*gen.pick(&[vn, 2 * vn, 4 * vn])).min(ks.max(1)).max(1);
            let n_t = (*gen.pick(&[1usize, 2, ah, 2 * ah])).min(ns.max(1)).max(1);
            let nbc = gen.pow2(0, 2).min(aw);
            let dup = gen.pow2(0, 2).min(aw / nbc).max(1);
            let ch = MappingChoice { df, vn, m_t, k_t, n_t, nbc, dup };
            let io = gen.usize(0, 5) as u8;
            let oo = gen.usize(0, 5) as u8;
            check(&cfg, &g, &ch, (io, 0, oo));
        });
    }
}
