//! Execution driver: replay a lowered program through the functional
//! simulator with real operand data, harvesting finished output tiles.
//!
//! Generic over the element backend ([`crate::arith::Element`]): the same
//! lowered trace executes saturating-i32, f32 or prime-field operands —
//! staging, harvesting and addressing are element-independent.
//!
//! This closes the correctness loop: mapper → MINISA trace → functional
//! simulation must reproduce a naive GEMM bit-exactly (and, in integration
//! tests, the PJRT-executed JAX/Pallas oracle).
//!
//! Error discipline: malformed operands or harvests surface as
//! [`SimError::Invalid`], never as panics — these entry points run on
//! mapper search threads and the serving leader, where a panic would take
//! the whole thread (and every queued candidate or co-batched request)
//! down with it.

use super::lower::{LoweredProgram, StagedOperand, Staging};
use crate::arch::config::ArchConfig;
use crate::arith::Element;
use crate::functional::{pack_image, BlockSim, FunctionalSim, SimError};
use crate::isa::inst::Inst;
use crate::mapping::Dataflow;
use crate::workloads::Gemm;

/// Materialize one staging region's buffer image from the logical operands.
fn stage_image<E: Element>(
    g: &Gemm,
    df: Dataflow,
    s: &Staging,
    iv: &[E],
    wv: &[E],
    aw: usize,
) -> Vec<E> {
    let vn = s.layout.vn_size;
    // Element accessors with global zero-padding.
    let from_i = |c: usize, r: usize, e: usize| -> E {
        // I[m, k] with m = nonred0 + c, k = k0 + r·vn + e.
        let m = s.nonred0 + c;
        let k = s.k0 + r * vn + e;
        if c >= s.nonred_t || m >= g.m || r * vn + e >= s.kt || k >= g.k {
            E::zero()
        } else {
            iv[m * g.k + k]
        }
    };
    let from_w = |c: usize, r: usize, e: usize| -> E {
        // W[k, n] with n = nonred0 + c, k = k0 + r·vn + e.
        let n = s.nonred0 + c;
        let k = s.k0 + r * vn + e;
        if c >= s.nonred_t || n >= g.n || r * vn + e >= s.kt || k >= g.k {
            E::zero()
        } else {
            wv[k * g.n + n]
        }
    };
    // Under WO-S the streamed tensor is I and the stationary is W; under
    // IO-S the roles (and the search-space transposition) swap them.
    let use_input = matches!(
        (df, s.operand),
        (Dataflow::WoS, StagedOperand::Streamed) | (Dataflow::IoS, StagedOperand::Stationary)
    );
    pack_image(&s.layout, aw, |r, c| {
        (0..vn).map(|e| if use_input { from_i(c, r, e) } else { from_w(c, r, e) }).collect()
    })
}

/// Replay a lowered program on real operands; returns the logical `M × N`
/// output (row-major accumulators — i64 for the default i32 backend).
pub fn execute_program<E: Element>(
    cfg: &ArchConfig,
    g: &Gemm,
    prog: &LoweredProgram,
    iv: &[E],
    wv: &[E],
) -> Result<Vec<E::Acc>, SimError> {
    let mut sim = FunctionalSim::new(cfg);
    execute_program_on(&mut sim, g, prog, iv, wv)
}

/// `execute_program` against a caller-provided simulator. Lets callers
/// reuse one simulator (and its compiled [`crate::functional::WavePlan`]
/// cache) across programs, or flip `sim.use_plans` to run the reference
/// interpreter (the plan-equivalence tests do both).
pub fn execute_program_on<E: Element>(
    sim: &mut FunctionalSim<E>,
    g: &Gemm,
    prog: &LoweredProgram,
    iv: &[E],
    wv: &[E],
) -> Result<Vec<E::Acc>, SimError> {
    if iv.len() != g.m * g.k {
        return Err(SimError::Invalid(format!(
            "input operand is {} elements, expected {}×{}",
            iv.len(),
            g.m,
            g.k
        )));
    }
    if wv.len() != g.k * g.n {
        return Err(SimError::Invalid(format!(
            "weight operand is {} elements, expected {}×{}",
            wv.len(),
            g.k,
            g.n
        )));
    }
    let aw = sim.cfg.aw;
    for s in &prog.staging {
        let img = stage_image(g, prog.choice.df, s, iv, wv, aw);
        debug_assert_eq!(img.len(), s.words);
        sim.hbm_write(s.hbm_addr, &img);
    }
    let mut out = vec![E::acc_zero(); g.m * g.n];
    let mut harvested = 0usize;
    for inst in &prog.trace.insts {
        if matches!(inst, Inst::SetOVNLayout(_)) && harvested > 0 {
            harvest(sim, g, prog, harvested - 1, &mut out)?;
        }
        if matches!(inst, Inst::SetOVNLayout(_)) {
            harvested += 1;
        }
        sim.exec(inst)?;
    }
    if harvested > 0 {
        harvest(sim, g, prog, harvested - 1, &mut out)?;
    }
    debug_assert_eq!(harvested, prog.harvests.len());
    Ok(out)
}

/// [`execute_program_on`] across a block of activation batches: lane `l`
/// executes the program against `ivs[l]` with the shared weights, with
/// every `ExecuteStreaming` tile running through the blocked multi-row
/// kernel ([`crate::functional::WavePlan::execute_rows`]). The
/// weight-operand staging image depends only on `wv`, so it is computed
/// **once** and broadcast to every lane's HBM; the activation operand is
/// staged per lane. Bit-exactness: lane `l`'s output and `SimStats` equal
/// a scalar `execute_program_on` run over `ivs[l]` alone.
pub fn execute_program_rows_on<E: Element>(
    block: &mut BlockSim<E>,
    g: &Gemm,
    prog: &LoweredProgram,
    ivs: &[Vec<E>],
    wv: &[E],
) -> Result<Vec<Vec<E::Acc>>, SimError> {
    let nl = ivs.len();
    if nl == 0 {
        return Ok(Vec::new());
    }
    for iv in ivs {
        if iv.len() != g.m * g.k {
            return Err(SimError::Invalid(format!(
                "input operand is {} elements, expected {}×{}",
                iv.len(),
                g.m,
                g.k
            )));
        }
    }
    if wv.len() != g.k * g.n {
        return Err(SimError::Invalid(format!(
            "weight operand is {} elements, expected {}×{}",
            wv.len(),
            g.k,
            g.n
        )));
    }
    let aw = block.cfg().aw;
    {
        let lanes = block.lanes_mut(nl);
        for s in &prog.staging {
            // Which logical tensor this staging region holds (mirrors
            // `stage_image`'s `use_input`): the activation differs per
            // lane, the weight image is lane-invariant.
            let stages_activation = matches!(
                (prog.choice.df, s.operand),
                (Dataflow::WoS, StagedOperand::Streamed)
                    | (Dataflow::IoS, StagedOperand::Stationary)
            );
            if stages_activation {
                for (sim, iv) in lanes.iter_mut().zip(ivs) {
                    let img = stage_image(g, prog.choice.df, s, iv, wv, aw);
                    debug_assert_eq!(img.len(), s.words);
                    sim.hbm_write(s.hbm_addr, &img);
                }
            } else {
                let img = stage_image(g, prog.choice.df, s, &ivs[0], wv, aw);
                debug_assert_eq!(img.len(), s.words);
                for sim in lanes.iter_mut() {
                    sim.hbm_write(s.hbm_addr, &img);
                }
            }
        }
    }
    let mut outs: Vec<Vec<E::Acc>> = (0..nl).map(|_| vec![E::acc_zero(); g.m * g.n]).collect();
    let mut harvested = 0usize;
    for inst in &prog.trace.insts {
        if matches!(inst, Inst::SetOVNLayout(_)) {
            if harvested > 0 {
                for (l, out) in outs.iter_mut().enumerate() {
                    harvest(block.lane(l), g, prog, harvested - 1, out)?;
                }
            }
            harvested += 1;
        }
        block.exec(inst, nl)?;
    }
    if harvested > 0 {
        for (l, out) in outs.iter_mut().enumerate() {
            harvest(block.lane(l), g, prog, harvested - 1, out)?;
        }
    }
    debug_assert_eq!(harvested, prog.harvests.len());
    Ok(outs)
}

fn harvest<E: Element>(
    sim: &FunctionalSim<E>,
    g: &Gemm,
    prog: &LoweredProgram,
    idx: usize,
    out: &mut [E::Acc],
) -> Result<(), SimError> {
    let h = &prog.harvests[idx];
    for p in 0..h.p_ext {
        for q in 0..h.q_ext {
            let (m, n) = (h.m0 + p, h.n0 + q);
            if m >= g.m || n >= g.n {
                continue;
            }
            let v = sim
                .output_element(p, q)
                .ok_or(SimError::Invalid(format!("harvest ({p},{q}) unmapped")))?;
            out[m * g.n + n] = v;
        }
    }
    Ok(())
}

/// Convenience: lower + execute + compare against the naive reference.
/// Returns (simulated output, reference output).
pub fn validate_decision(
    cfg: &ArchConfig,
    g: &Gemm,
    prog: &LoweredProgram,
    seed: u64,
) -> Result<(Vec<i64>, Vec<i64>), SimError> {
    let mut rng = crate::util::Lcg::new(seed);
    let iv: Vec<i32> = (0..g.m * g.k).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let wv: Vec<i32> = (0..g.k * g.n).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let got = execute_program(cfg, g, prog, &iv, &wv)?;
    let expect = crate::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
    Ok((got, expect))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::lower::lower_gemm;
    use crate::mapper::MappingChoice;
    use crate::util::prop::forall;

    /// Validate one (chain, orders) candidate, propagating failures as
    /// `Err` with full context instead of panicking (the former `panic!`
    /// here is exactly what the search-thread error-propagation satellite
    /// removed — callers decide whether a failure is fatal).
    fn check(
        cfg: &ArchConfig,
        g: &Gemm,
        ch: &MappingChoice,
        orders: (u8, u8, u8),
    ) -> Result<(), String> {
        let prog = lower_gemm(cfg, g, ch, orders.0, orders.1, orders.2);
        let (got, expect) = validate_decision(cfg, g, &prog, 42)
            .map_err(|e| format!("{g} {ch:?} orders {orders:?}: {e}"))?;
        if got != expect {
            return Err(format!("{g} {ch:?} orders {orders:?}: functional mismatch"));
        }
        Ok(())
    }

    #[test]
    fn exact_single_tile() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 8, 8, 8);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        check(&cfg, &g, &ch, (0, 0, 0)).unwrap();
    }

    #[test]
    fn multi_tile_all_dims() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 12, 20, 10);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        check(&cfg, &g, &ch, (0, 0, 0)).unwrap();
    }

    #[test]
    fn duplication_and_nbc_variants() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 16, 8, 16);
        for (nbc, dup) in [(1usize, 1usize), (2, 1), (1, 2), (2, 2), (4, 1), (1, 4)] {
            let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 16, k_t: 8, n_t: 16, nbc, dup };
            check(&cfg, &g, &ch, (0, 0, 0)).unwrap();
        }
    }

    #[test]
    fn ios_dataflow_exact() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 6, 8, 12);
        let ch = MappingChoice { df: Dataflow::IoS, vn: 4, m_t: 16, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        check(&cfg, &g, &ch, (0, 0, 0)).unwrap();
    }

    #[test]
    fn all_layout_orders_preserve_semantics() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 8, 12, 8);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 12, n_t: 8, nbc: 2, dup: 2 };
        for io in 0..6u8 {
            for oo in 0..6u8 {
                check(&cfg, &g, &ch, (io, 0, oo)).unwrap();
            }
        }
        for wo in 0..6u8 {
            check(&cfg, &g, &ch, (0, wo, 0)).unwrap();
        }
    }

    #[test]
    fn randomized_mapper_correctness() {
        // The core property of the whole stack: any legal decision lowers
        // to a trace whose functional execution equals the naive GEMM.
        forall("mapper-lowering-exact", 60, |gen| {
            let (ah, aw) = *gen.pick(&[(4usize, 4usize), (4, 8), (8, 8)]);
            let cfg = ArchConfig::paper(ah, aw);
            let m = gen.usize(1, 24);
            let k = gen.usize(1, 24);
            let n = gen.usize(1, 24);
            let g = Gemm::new("p", "prop", m, k, n);
            let vn = ah.min(k).max(1);
            let df = if gen.bool() { Dataflow::WoS } else { Dataflow::IoS };
            let (ms, ks, ns) = crate::mapper::lower::search_dims(&g, df);
            let m_t = gen.pick(&[ah, 2 * ah, 4 * ah]).min(&ms.max(1)).to_owned().max(1);
            let k_t = (*gen.pick(&[vn, 2 * vn, 4 * vn])).min(ks.max(1)).max(1);
            let n_t = (*gen.pick(&[1usize, 2, ah, 2 * ah])).min(ns.max(1)).max(1);
            let nbc = gen.pow2(0, 2).min(aw);
            let dup = gen.pow2(0, 2).min(aw / nbc).max(1);
            let ch = MappingChoice { df, vn, m_t, k_t, n_t, nbc, dup };
            let io = gen.usize(0, 5) as u8;
            let oo = gen.usize(0, 5) as u8;
            check(&cfg, &g, &ch, (io, 0, oo)).unwrap();
        });
    }

    /// Malformed operands propagate as `SimError::Invalid`, not a panic —
    /// the driver is safe to call from search threads and the serving
    /// leader with untrusted shapes.
    #[test]
    fn bad_operand_shapes_error_instead_of_panicking() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 8, 8, 8);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        let prog = lower_gemm(&cfg, &g, &ch, 0, 0, 0);
        let wv = vec![1i32; g.k * g.n];
        let r = execute_program(&cfg, &g, &prog, &[1i32; 3], &wv);
        assert!(matches!(r, Err(SimError::Invalid(_))), "{r:?}");
        let iv = vec![1i32; g.m * g.k];
        let r = execute_program(&cfg, &g, &prog, &iv, &[1i32; 3]);
        assert!(matches!(r, Err(SimError::Invalid(_))), "{r:?}");
    }
}
