//! Step 7 — deterministic lowering of a (mapping, layout) decision into a
//! MINISA instruction trace plus the per-invocation `TilePlan` schedule the
//! performance model consumes (§V-B7).
//!
//! Loop nest (original-coordinate GEMM `O[M,N] = I[M,K]·W[K,N]`; under IO-S
//! the search space is the transposed problem, §V-B):
//!
//! ```text
//! for m-tile, n-tile:            # output tile: SetOVNLayout (+ commit)
//!   for k-tile:                  # reduction chunk: Loads + layouts
//!     for nb-chunk, kg-chunk:    # one ExecuteMapping/ExecuteStreaming pair
//! ```
//!
//! One invocation covers `kgc` reduction tiles × `nbc` output-column blocks
//! × `dup`-way streamed splitting, per the unified Eq.-(1) parameterization:
//! `G_r = nbc·dup`, `G_c = nbc`, `s_r = 1`, `s_c = AH`, `s_m = dup`.

use super::MappingChoice;
use crate::arch::config::ArchConfig;
use crate::isa::inst::{BufTarget, Inst, LayoutInst};
use crate::isa::{encode::Codec, Trace};
use crate::layout::VnLayout;
use crate::mapping::{Dataflow, MappingCfg, StreamCfg};
use crate::perf::TilePlan;
use crate::util::ceil_div;
use crate::workloads::Gemm;

/// Which operand an HBM staging region feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagedOperand {
    /// The streamed tensor (I under WO-S, W under IO-S) → streaming buffer.
    Streamed,
    /// The stationary tensor (W under WO-S, I under IO-S) → stationary buf.
    Stationary,
}

/// One HBM region the execution driver must materialize before replaying
/// the trace: the buffer image of a tile of one operand.
#[derive(Debug, Clone)]
pub struct Staging {
    pub operand: StagedOperand,
    pub hbm_addr: u64,
    pub words: usize,
    pub layout: VnLayout,
    /// Reduction-rank element base (k offset) of this tile.
    pub k0: usize,
    /// Non-reduction-rank element base in *search space* (m' for streamed,
    /// n' for stationary).
    pub nonred0: usize,
    /// Tile extents (reduction, non-reduction) in elements.
    pub kt: usize,
    pub nonred_t: usize,
}

/// Where to harvest a finished output tile (original coordinates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Harvest {
    pub m0: usize,
    pub n0: usize,
    pub p_ext: usize,
    pub q_ext: usize,
}

/// A lowered program: trace + schedule + staging/harvest metadata.
#[derive(Debug, Clone)]
pub struct LoweredProgram {
    pub choice: MappingChoice,
    pub i_order: u8,
    pub w_order: u8,
    pub o_order: u8,
    pub trace: Trace,
    /// One plan per NEST invocation, in trace order.
    pub plans: Vec<TilePlan>,
    pub staging: Vec<Staging>,
    /// One harvest per output tile, in SetOVNLayout order.
    pub harvests: Vec<Harvest>,
    pub minisa_bits: u64,
    pub micro_bits: u64,
    pub waves: u64,
    pub invocations: u64,
    pub macs: u64,
}

impl LoweredProgram {
    pub fn minisa_bytes(&self) -> u64 {
        self.minisa_bits.div_ceil(8)
    }
    pub fn micro_bytes(&self) -> u64 {
        self.micro_bits.div_ceil(8)
    }
    /// Off-chip instruction-traffic reduction factor (Fig. 12).
    pub fn instr_reduction(&self) -> f64 {
        self.micro_bits as f64 / self.minisa_bits.max(1) as f64
    }
}

/// Search-space view of the GEMM under a dataflow (§V-B: IO-S is the
/// transposed WO-S).
pub fn search_dims(g: &Gemm, df: Dataflow) -> (usize, usize, usize) {
    match df {
        Dataflow::WoS => (g.m, g.k, g.n),
        Dataflow::IoS => (g.n, g.k, g.m),
    }
}

/// Streamed-operand layout for a tile: level-0 factor = `dup` (the m-split
/// granularity), which lets order 100 (`m_L1 → j_L1 → m_L0`) place each
/// wave's working set in one buffer row-block.
pub fn streamed_layout(choice: &MappingChoice, mt: usize, kgt: usize, order: u8) -> VnLayout {
    let l0 = choice.dup.min(mt.max(1));
    VnLayout::new(order, l0, ceil_div(mt.max(1), l0), kgt.max(1), choice.vn)
}

/// Stationary-operand layout for a tile.
pub fn stationary_layout(cfg: &ArchConfig, choice: &MappingChoice, nt: usize, kgt: usize, order: u8) -> VnLayout {
    let l0 = cfg.aw.min(nt.max(1));
    VnLayout::new(order, l0, ceil_div(nt.max(1), l0), kgt.max(1), choice.vn)
}

/// Output layout for a tile (`p_ext × q_ext` in original coordinates).
pub fn output_layout(cfg: &ArchConfig, choice: &MappingChoice, p_ext: usize, q_ext: usize, order: u8) -> VnLayout {
    let l0 = cfg.aw.min(p_ext.max(1));
    VnLayout::new(
        order,
        l0,
        ceil_div(p_ext.max(1), l0),
        ceil_div(q_ext.max(1), choice.vn).max(1),
        choice.vn,
    )
}

/// Streaming-buffer row-block serialization factor for one wave (§V-B6b):
/// FEATHER+'s single-bank streaming buffer reads one row per cycle and the
/// crossbar multicasts it; a wave touching `b` distinct VN row-blocks needs
/// `b` row reads per element cycle.
pub fn stream_block_factor(
    cfg: &ArchConfig,
    choice: &MappingChoice,
    layout: &VnLayout,
    em: &MappingCfg,
    es: &StreamCfg,
) -> usize {
    let mut max_blocks = 1usize;
    for t in 0..es.t.min(3) {
        let mut blocks: Vec<usize> = Vec::with_capacity(cfg.aw);
        for a_w in 0..cfg.aw {
            let (m, j) = es.streamed_vn(em, a_w, t);
            if let Some(l) = layout.flatten(j, m) {
                blocks.push(l / cfg.aw);
            }
        }
        blocks.sort_unstable();
        blocks.dedup();
        max_blocks = max_blocks.max(blocks.len().max(1));
    }
    let _ = choice;
    max_blocks
}

/// Output-buffer pressure factor for one wave: each bank absorbs one write
/// per cycle (`vn` per wave); more distinct rows per bank serialize.
pub fn ob_pressure_factor(
    cfg: &ArchConfig,
    choice: &MappingChoice,
    o_layout: &VnLayout,
    em: &MappingCfg,
    es: &StreamCfg,
    p_ext: usize,
    q_ext: usize,
) -> usize {
    let vn = choice.vn;
    let mut per_bank = vec![0usize; cfg.aw];
    let active_rows = vn.min(cfg.ah);
    // Output addresses are periodic in the PE-column index with period G_r:
    // columns in different kg-groups compute the *same* (p, q) set (their
    // psums reduce spatially in BIRRD), so probing one period is exact and
    // avoids an O(AH·AW) hash per candidate (§Perf optimization).
    let period = em.g_r.min(cfg.aw).max(1);
    let mut writes: Vec<(usize, usize)> = Vec::with_capacity(period * active_rows);
    for a_w in 0..period {
        let (m, _j) = es.streamed_vn(em, a_w, 0);
        for a_h in 0..active_rows {
            let (_r, c) = em.stationary_vn(a_h, a_w);
            let (p, q) = match es.df {
                Dataflow::WoS => (m, c),
                Dataflow::IoS => (c, m),
            };
            if p >= p_ext || q >= q_ext {
                continue;
            }
            let (r_o, off, c_o) = (q / vn, q % vn, p);
            if let Some((row0, bank)) = o_layout.addr(r_o, c_o, cfg.aw) {
                writes.push((row0 + off, bank));
            }
        }
    }
    writes.sort_unstable();
    writes.dedup();
    for &(_, bank) in &writes {
        per_bank[bank] += 1;
    }
    let worst = per_bank.iter().copied().max().unwrap_or(0);
    ceil_div(worst.max(1), vn).max(1)
}

/// Lower a GEMM under a fully-resolved decision. Returns the trace, the
/// per-invocation schedule and the staging/harvest metadata.
pub fn lower_gemm(
    cfg: &ArchConfig,
    g: &Gemm,
    choice: &MappingChoice,
    i_order: u8,
    w_order: u8,
    o_order: u8,
) -> LoweredProgram {
    let (ms, ks, ns) = search_dims(g, choice.df);
    let vn = choice.vn;
    let ah = cfg.ah;
    let aw = cfg.aw;
    let codec = Codec::new(cfg);
    let mut trace = Trace::new();
    let mut plans: Vec<TilePlan> = Vec::new();
    let mut staging: Vec<Staging> = Vec::new();
    let mut harvests: Vec<Harvest> = Vec::new();
    let mut hbm_top: u64 = 0;
    let mut waves: u64 = 0;
    let mut invocations: u64 = 0;
    let mut micro_bits: u64 = 0;

    let n_mt = ceil_div(ms, choice.m_t);
    let n_kt = ceil_div(ks, choice.k_t);
    let n_nt = ceil_div(ns, choice.n_t);
    let micro = crate::microinst::cost(cfg, vn);

    trace.begin_layer();
    for mi in 0..n_mt {
        let m0 = mi * choice.m_t;
        let mt = choice.m_t.min(ms - m0);
        for ni in 0..n_nt {
            let n0 = ni * choice.n_t;
            let nt = choice.n_t.min(ns - n0);
            // Output tile in original coordinates.
            let (om0, on0, p_ext, q_ext) = match choice.df {
                Dataflow::WoS => (m0, n0, mt, nt),
                Dataflow::IoS => (n0, m0, nt, mt),
            };
            let o_lay = output_layout(cfg, choice, p_ext, q_ext, o_order);
            trace.push(Inst::SetOVNLayout(LayoutInst { layout: o_lay }));
            harvests.push(Harvest { m0: om0, n0: on0, p_ext, q_ext });

            for ki in 0..n_kt {
                let k0 = ki * choice.k_t;
                let kt = choice.k_t.min(ks - k0);
                let kgt = ceil_div(kt, vn);
                // Only vn PE rows are active when VN_size < AH (§VI-D2), so
                // output-column blocks are vn-sized.
                let rows_active = vn.min(ah);
                let nbt = ceil_div(nt, rows_active);
                let i_lay = streamed_layout(choice, mt, kgt, i_order);
                let w_lay = stationary_layout(cfg, choice, nt, kgt, w_order);
                // Stage + load both operands.
                let str_rows = i_lay.rows_needed(aw);
                let sta_rows = w_lay.rows_needed(aw);
                let str_addr = hbm_top;
                hbm_top += (str_rows * aw) as u64;
                let sta_addr = hbm_top;
                hbm_top += (sta_rows * aw) as u64;
                staging.push(Staging {
                    operand: StagedOperand::Streamed,
                    hbm_addr: str_addr,
                    words: str_rows * aw,
                    layout: i_lay,
                    k0,
                    nonred0: m0,
                    kt,
                    nonred_t: mt,
                });
                staging.push(Staging {
                    operand: StagedOperand::Stationary,
                    hbm_addr: sta_addr,
                    words: sta_rows * aw,
                    layout: w_lay,
                    k0,
                    nonred0: n0,
                    kt,
                    nonred_t: nt,
                });
                trace.push(Inst::Load {
                    target: BufTarget::Streaming,
                    hbm_addr: str_addr,
                    rows: str_rows as u32,
                });
                trace.push(Inst::Load {
                    target: BufTarget::Stationary,
                    hbm_addr: sta_addr,
                    rows: sta_rows as u32,
                });
                // Layout setters: streamed tensor's layout instruction is
                // SetIVNLayout under WO-S (inputs stream) and SetWVNLayout
                // under IO-S (weights stream), and vice versa.
                match choice.df {
                    Dataflow::WoS => {
                        trace.push(Inst::SetIVNLayout(LayoutInst { layout: i_lay }));
                        trace.push(Inst::SetWVNLayout(LayoutInst { layout: w_lay }));
                    }
                    Dataflow::IoS => {
                        trace.push(Inst::SetWVNLayout(LayoutInst { layout: i_lay }));
                        trace.push(Inst::SetIVNLayout(LayoutInst { layout: w_lay }));
                    }
                }
                // Invocations: nb-chunks × kg-chunks.
                let period = (choice.nbc * choice.dup).min(aw).max(1);
                let kgc = (aw / period).max(1);
                let t_steps = ceil_div(mt, choice.dup).max(1);
                let mut first_inv_of_tile = true;
                for nb0 in (0..nbt).step_by(choice.nbc) {
                    for kg0 in (0..kgt).step_by(kgc) {
                        let em = MappingCfg {
                            r0: kg0,
                            c0: nb0 * rows_active,
                            g_r: period,
                            g_c: choice.nbc,
                            s_r: 1,
                            s_c: rows_active,
                        };
                        let es = StreamCfg {
                            df: choice.df,
                            m0: 0,
                            s_m: choice.dup,
                            t: t_steps,
                            vn_size: vn,
                        };
                        trace.push(Inst::ExecuteMapping(em));
                        trace.push(Inst::ExecuteStreaming(es));
                        // Per-invocation schedule entry.
                        let sf = stream_block_factor(cfg, choice, &i_lay, &em, &es);
                        let of = ob_pressure_factor(
                            cfg, choice, &o_lay, &em, &es, p_ext, q_ext,
                        );
                        let factor = sf.max(of) as u64;
                        let t_waves = t_steps as u64;
                        let kg_here = kgc.min(kgt - kg0);
                        let nb_here = choice.nbc.min(nbt - nb0);
                        // Useful MACs: actual element triples covered.
                        let n_here = (nb_here * rows_active).min(nt - nb0 * rows_active);
                        let k_here = (kg_here * vn).min(kt - kg0 * vn);
                        let macs_used = (mt * k_here * n_here) as u64;
                        let mut plan = TilePlan {
                            instr_bits: (codec.bw.execute_mapping()
                                + codec.bw.execute_streaming())
                                as u64,
                            compute_cycles: t_waves * vn as u64 * factor,
                            fill_cycles: if invocations == 0 { vn as u64 } else { 0 },
                            drain_cycles: cfg.drain_cycles() as u64,
                            macs_used,
                            ..Default::default()
                        };
                        if first_inv_of_tile {
                            // Preamble bits + data loads ride on the first
                            // invocation of the k-tile.
                            plan.instr_bits += 2 * codec.bw.load_store() as u64
                                + 2 * codec.bw.set_layout() as u64;
                            if ki == 0 {
                                plan.instr_bits += codec.bw.set_layout() as u64; // SetOVN
                            }
                            plan.load_in_words = (mt * kt) as u64;
                            plan.load_w_words = (kt * nt) as u64;
                            first_inv_of_tile = false;
                        }
                        if ki == n_kt - 1 && kg0 + kgc >= kgt && nb0 + choice.nbc >= nbt {
                            // Last invocation of the output tile: drain.
                            plan.out_stream_words = (p_ext * q_ext) as u64;
                            plan.store_out_words = (p_ext * q_ext) as u64;
                            plan.instr_bits += codec.bw.load_store() as u64; // Store
                        }
                        waves += t_waves;
                        invocations += 1;
                        micro_bits += t_waves * micro.bits_per_wave + micro.bits_per_invocation;
                        plans.push(plan);
                    }
                }
            }
            // Drain the finished output tile off-chip via the streaming
            // buffer (Out→Stream then Store — §VI-C2 components).
            let out_rows = o_lay.rows_needed(aw).min(cfg.d_str()) as u32;
            let out_addr = hbm_top;
            hbm_top += (out_rows as usize * aw) as u64;
            trace.push(Inst::Store {
                target: BufTarget::Streaming,
                hbm_addr: out_addr,
                rows: out_rows.max(1),
            });
        }
    }
    let minisa_bits = trace.size_bits(&codec);
    // Micro twin also re-fetches data movement descriptors; dominated by
    // the per-wave stream, already counted.
    LoweredProgram {
        choice: *choice,
        i_order,
        w_order,
        o_order,
        trace,
        plans,
        staging,
        harvests,
        minisa_bits,
        micro_bits,
        waves,
        invocations,
        macs: g.macs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ArchConfig {
        ArchConfig::paper(4, 4)
    }

    fn small_choice() -> MappingChoice {
        MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 1, dup: 1 }
    }

    #[test]
    fn lowering_structure_counts() {
        let g = Gemm::new("t", "test", 8, 8, 8);
        let p = lower_gemm(&cfg(), &g, &small_choice(), 0, 0, 0);
        // Single tile: kg_t = 2, nb_t = 2, period = 1·1, kgc = 4 → one
        // kg-chunk; nb chunks = 2 → 2 invocations.
        assert_eq!(p.invocations, 2);
        assert_eq!(p.harvests.len(), 1);
        assert_eq!(p.trace.tile_count(), 2);
        assert_eq!(p.plans.len(), 2);
        // waves = invocations × T = 2 × 8.
        assert_eq!(p.waves, 16);
        assert_eq!(p.macs, 512);
    }

    #[test]
    fn trace_sizes_scale_with_tiles_not_waves() {
        // MINISA's core claim: instruction bits independent of M.
        let c = cfg();
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 4096, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        let g1 = Gemm::new("a", "t", 4096, 8, 8);
        let p1 = lower_gemm(&c, &g1, &ch, 0, 0, 0);
        let g2 = Gemm::new("b", "t", 4096 * 4, 8, 8);
        let ch2 = MappingChoice { m_t: 4096 * 4, ..ch };
        let p2 = lower_gemm(&c, &g2, &ch2, 0, 0, 0);
        // 16× the waves, same trace size (same tile/invocation count).
        assert_eq!(p1.invocations, p2.invocations);
        assert_eq!(p1.minisa_bits, p2.minisa_bits);
        assert!(p2.waves == 4 * p1.waves);
        // Micro bits scale with waves instead.
        assert!(p2.micro_bits > 3 * p1.micro_bits);
    }

    #[test]
    fn instr_reduction_grows_with_array() {
        let g = Gemm::new("t", "test", 1024, 40, 88);
        let mk = |ah: usize, aw: usize| {
            let c = ArchConfig::paper(ah, aw);
            let ch = MappingChoice {
                df: Dataflow::WoS,
                vn: ah,
                m_t: 1024,
                k_t: 40,
                n_t: 88,
                nbc: 1,
                dup: 1,
            };
            lower_gemm(&c, &g, &ch, 0, 0, 0).instr_reduction()
        };
        let small = mk(4, 4);
        let large = mk(16, 256);
        assert!(small > 10.0, "even 4x4 reduces: {small}");
        assert!(large > small, "reduction grows with scale: {large} vs {small}");
    }

    #[test]
    fn edge_tiles_cover_remainders() {
        let g = Gemm::new("t", "test", 10, 10, 10);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        let p = lower_gemm(&cfg(), &g, &ch, 0, 0, 0);
        // 2×2×2 tile grid → 4 harvests (m×n), 8 k-tiles total.
        assert_eq!(p.harvests.len(), 4);
        let h: usize = p.harvests.iter().map(|h| h.p_ext * h.q_ext).sum();
        assert_eq!(h, 100); // full output coverage
    }

    #[test]
    fn ios_transposes_harvest_coordinates() {
        let g = Gemm::new("t", "test", 6, 8, 12);
        let ch = MappingChoice { df: Dataflow::IoS, vn: 4, m_t: 16, k_t: 8, n_t: 8, nbc: 1, dup: 1 };
        let p = lower_gemm(&cfg(), &g, &ch, 0, 0, 0);
        // Search space is (12, 8, 6); harvests map back to original (M=6 →
        // p from stationary side, N=12 → q from streamed side).
        let total: usize = p.harvests.iter().map(|h| h.p_ext * h.q_ext).sum();
        assert_eq!(total, 72);
        for h in &p.harvests {
            assert!(h.m0 + h.p_ext <= 6);
            assert!(h.n0 + h.q_ext <= 12);
        }
    }

    #[test]
    fn plans_align_with_trace_invocations() {
        let g = Gemm::new("t", "test", 32, 16, 16);
        let ch = MappingChoice { df: Dataflow::WoS, vn: 4, m_t: 32, k_t: 16, n_t: 16, nbc: 2, dup: 2 };
        let p = lower_gemm(&cfg(), &g, &ch, 4, 0, 0);
        assert_eq!(p.plans.len() as u64, p.invocations);
        assert_eq!(p.trace.tile_count() as u64, p.invocations);
        // Every plan has compute work.
        assert!(p.plans.iter().all(|t| t.compute_cycles > 0));
        // Loads appear on first invocation of each k-tile.
        let with_loads = p.plans.iter().filter(|t| t.load_in_words > 0).count();
        assert_eq!(with_loads, 1); // single k-tile here
    }

    #[test]
    fn macs_used_totals_match_gemm() {
        for (m, k, n) in [(8usize, 8usize, 8usize), (10, 12, 6), (32, 40, 24)] {
            let g = Gemm::new("t", "test", m, k, n);
            let ch = MappingChoice {
                df: Dataflow::WoS,
                vn: 4,
                m_t: 8,
                k_t: 8,
                n_t: 8,
                nbc: 1,
                dup: 1,
            };
            let p = lower_gemm(&cfg(), &g, &ch, 0, 0, 0);
            let used: u64 = p.plans.iter().map(|t| t.macs_used).sum();
            assert_eq!(used, g.macs(), "({m},{k},{n})");
        }
    }
}
