//! Capacity-bounded LRU cache of **loaded** programs, shared across every
//! session (and every fleet device) that serves the same content hash.
//!
//! The cache value is the expensive part of `Server::register`: the decoded
//! [`Program`] (wave plans compiled) plus the weights in their decoded
//! per-backend form. A hit hands back `Arc`s, so N sessions of one blob
//! share **one** weight allocation — the zero-copy guarantee the tests
//! prove by pointer identity. Hit/miss/eviction totals surface in the
//! serving metrics registry as `registry_{hits,misses,evictions}_total`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::arith::ElemType;
use crate::coordinator::serve::WordWeights;
use crate::program::Program;

use super::RegistryKey;

/// Decoded session weights in their serving form — the same split the
/// server keeps per session (`f32` sessions serve `Payload::Program`,
/// everything else serves canonical words).
#[derive(Clone)]
pub enum LoadedWeights {
    F32(Arc<Vec<Vec<f32>>>),
    Words(Arc<WordWeights>),
}

/// One fully-loaded registry entry: compiled program + decoded weights.
pub struct LoadedProgram {
    pub key: RegistryKey,
    pub program: Arc<Program>,
    pub elem: ElemType,
    pub weights: LoadedWeights,
}

/// Point-in-time cache statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub len: usize,
    pub capacity: usize,
}

struct Inner {
    /// Key string → entry.
    map: HashMap<String, Arc<LoadedProgram>>,
    /// Recency order, front = least recently used.
    order: Vec<String>,
}

/// The LRU itself. All structural state sits behind one mutex (entries are
/// few and large — contention is on the *contents*, which are `Arc`-shared
/// outside the lock); the counters are lock-free so hot-path reads of the
/// stats never serialize against inserts.
pub struct ProgramCache {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ProgramCache {
    /// A cache holding at most `capacity` loaded programs. Capacity 0
    /// disables caching entirely (every lookup is a miss, nothing is
    /// retained).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            inner: Mutex::new(Inner { map: HashMap::new(), order: Vec::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&self, key: &str) -> Option<Arc<LoadedProgram>> {
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get(key).cloned() {
            Some(v) => {
                if let Some(at) = inner.order.iter().position(|k| k == key) {
                    let k = inner.order.remove(at);
                    inner.order.push(k);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) `key`, evicting least-recently-used entries to
    /// stay within capacity. Returns how many entries were evicted by this
    /// insert. Under concurrent loads of one key the last writer wins —
    /// both callers hold complete, valid entries either way.
    pub fn insert(&self, key: &str, value: Arc<LoadedProgram>) -> u64 {
        if self.capacity == 0 {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.map.insert(key.to_string(), value).is_none() {
            inner.order.push(key.to_string());
        } else if let Some(at) = inner.order.iter().position(|k| k == key) {
            let k = inner.order.remove(at);
            inner.order.push(k);
        }
        let mut evicted = 0;
        while inner.order.len() > self.capacity {
            let lru = inner.order.remove(0);
            inner.map.remove(&lru);
            evicted += 1;
        }
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        evicted
    }

    /// Drop `key` if cached (a gc'd or re-put blob must not serve stale).
    pub fn invalidate(&self, key: &str) {
        let mut inner = self.inner.lock().unwrap();
        if inner.map.remove(key).is_some() {
            inner.order.retain(|k| k != key);
        }
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            len: self.len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::mapper::chain::Chain;

    fn entry(tag: u64) -> Arc<LoadedProgram> {
        // A real (tiny) program so the cache holds what production holds.
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("c", 4, &[4, 4]);
        let program = crate::program::Program::compile(
            &cfg,
            &chain,
            &crate::mapper::search::MapperOptions {
                full_layout_search: false,
                threads: 1,
                ..Default::default()
            },
        )
        .unwrap();
        Arc::new(LoadedProgram {
            key: RegistryKey { content: tag, arch: 1 },
            program: Arc::new(program),
            elem: ElemType::F32,
            weights: LoadedWeights::F32(Arc::new(vec![vec![0.0; 16]])),
        })
    }

    #[test]
    fn lru_evicts_oldest_and_counts() {
        let c = ProgramCache::new(2);
        assert!(c.get("aa").is_none());
        c.insert("aa", entry(1));
        c.insert("bb", entry(2));
        // Touch aa so bb is the LRU victim.
        assert!(c.get("aa").is_some());
        let evicted = c.insert("cc", entry(3));
        assert_eq!(evicted, 1);
        assert!(c.get("bb").is_none(), "bb was the least recently used");
        assert!(c.get("aa").is_some());
        assert!(c.get("cc").is_some());
        let s = c.stats();
        assert_eq!(s.len, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.capacity, 2);
        assert!(s.hits >= 3 && s.misses >= 2);
    }

    #[test]
    fn hit_shares_the_same_allocation() {
        let c = ProgramCache::new(4);
        c.insert("aa", entry(7));
        let a = c.get("aa").unwrap();
        let b = c.get("aa").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let (LoadedWeights::F32(wa), LoadedWeights::F32(wb)) = (&a.weights, &b.weights) else {
            panic!("f32 entry");
        };
        assert!(Arc::ptr_eq(wa, wb), "one weight buffer behind every hit");
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = ProgramCache::new(0);
        assert_eq!(c.insert("aa", entry(1)), 0);
        assert!(c.get("aa").is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn invalidate_drops_entry() {
        let c = ProgramCache::new(4);
        c.insert("aa", entry(1));
        c.invalidate("aa");
        assert!(c.get("aa").is_none());
        assert_eq!(c.len(), 0);
    }
}
