//! Weights-only **delta artifacts** — the fine-tune-redeploy container.
//!
//! A delta ships only new canonical weight words plus the content hash of
//! its base artifact; the base's trace, decisions, and arch section are
//! reused verbatim at resolve time. Because the compiler is deterministic,
//! composing `base + delta weights` reproduces, byte for byte, what a full
//! recompile of the same chain with the new weights would produce — the
//! delta's key *is* the content hash of that composed container, and
//! [`super::Registry::resolve`] re-verifies it on every load.
//!
//! Wire format (little-endian, `docs/REGISTRY.md`):
//!
//! ```text
//! magic "MINISAdl" | u16 version | u64 base_content | u64 arch_fingerprint
//! | u64 composed_content | u8 elem_tag | u32 n_layers
//! | n_layers × (u32 len, len × u64 words) | u64 fnv64 checksum
//! ```

use crate::arith::ElemType;
use crate::artifact::{elem_from_tag, elem_tag, fnv64};

use super::RegistryError;

/// Delta container magic.
pub const DELTA_MAGIC: [u8; 8] = *b"MINISAdl";
/// Delta wire-format version (same compatibility rule as the artifact
/// container: readers reject foreign versions).
pub const DELTA_VERSION: u16 = 1;

/// Layer-count cap: a lying header must fail on the truncated read that
/// follows, not on an absurd up-front allocation.
const MAX_LAYERS: usize = 1 << 16;

/// A parsed weights-only delta.
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    /// Content hash of the base artifact this delta patches.
    pub base_content: u64,
    /// Arch fingerprint — must match the base's (a delta never crosses
    /// architectures; recompile for that).
    pub arch: u64,
    /// Content hash of the *composed* artifact (base + these weights):
    /// the delta's own registry key, re-verified at resolve.
    pub composed_content: u64,
    /// Element type of the replacement weights.
    pub elem: ElemType,
    /// One canonical-word matrix per chain layer.
    pub weights: Vec<Vec<u64>>,
}

impl Delta {
    /// Serialize to the delta wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&DELTA_MAGIC);
        b.extend_from_slice(&DELTA_VERSION.to_le_bytes());
        b.extend_from_slice(&self.base_content.to_le_bytes());
        b.extend_from_slice(&self.arch.to_le_bytes());
        b.extend_from_slice(&self.composed_content.to_le_bytes());
        b.push(elem_tag(self.elem));
        b.extend_from_slice(&(self.weights.len() as u32).to_le_bytes());
        for m in &self.weights {
            b.extend_from_slice(&(m.len() as u32).to_le_bytes());
            for &w in m {
                b.extend_from_slice(&w.to_le_bytes());
            }
        }
        let ck = fnv64(&b);
        b.extend_from_slice(&ck.to_le_bytes());
        b
    }

    /// Parse and checksum-validate a delta container.
    pub fn from_bytes(bytes: &[u8]) -> Result<Delta, RegistryError> {
        let corrupt = |m: &str| RegistryError::Corrupt(format!("delta: {m}"));
        if bytes.len() < DELTA_MAGIC.len() + 2 + 8 || bytes[..DELTA_MAGIC.len()] != DELTA_MAGIC {
            return Err(corrupt("bad magic or truncated"));
        }
        let body = bytes.len() - 8;
        let stored = u64::from_le_bytes(bytes[body..].try_into().unwrap());
        if fnv64(&bytes[..body]) != stored {
            return Err(corrupt("checksum mismatch"));
        }
        let mut r = DeltaReader { bytes: &bytes[..body], pos: DELTA_MAGIC.len() };
        let version = u16::from_le_bytes(r.take(2)?.try_into().unwrap());
        if version != DELTA_VERSION {
            return Err(corrupt(&format!(
                "version {version} unsupported (this build reads {DELTA_VERSION})"
            )));
        }
        let base_content = r.u64()?;
        let arch = r.u64()?;
        let composed_content = r.u64()?;
        let elem = elem_from_tag(r.take(1)?[0]).map_err(RegistryError::Artifact)?;
        let n_layers = u32::from_le_bytes(r.take(4)?.try_into().unwrap()) as usize;
        if n_layers == 0 || n_layers > MAX_LAYERS {
            return Err(corrupt(&format!("implausible layer count {n_layers}")));
        }
        let mut weights = Vec::with_capacity(n_layers.min(1024));
        for _ in 0..n_layers {
            let len = u32::from_le_bytes(r.take(4)?.try_into().unwrap()) as usize;
            let raw = r.take(len.checked_mul(8).ok_or(corrupt("layer too large"))?)?;
            weights.push(
                raw.chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            );
        }
        if r.pos != body {
            return Err(corrupt("trailing bytes"));
        }
        Ok(Delta { base_content, arch, composed_content, elem, weights })
    }

    /// Cheap header sniff: `Some(base_content)` iff `bytes` starts like a
    /// delta container (used by gc to chase base links without a full
    /// parse of every blob).
    pub fn sniff_base(bytes: &[u8]) -> Option<u64> {
        if bytes.len() >= DELTA_MAGIC.len() + 2 + 8 && bytes[..DELTA_MAGIC.len()] == DELTA_MAGIC {
            let at = DELTA_MAGIC.len() + 2;
            Some(u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap()))
        } else {
            None
        }
    }
}

/// Bounds-checked cursor over the checksummed body.
struct DeltaReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> DeltaReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], RegistryError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| RegistryError::Corrupt("delta: truncated".into()))?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64, RegistryError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Delta {
        Delta {
            base_content: 0x1111_2222_3333_4444,
            arch: 0xaaaa_bbbb_cccc_dddd,
            composed_content: 0x5555_6666_7777_8888,
            elem: ElemType::Goldilocks,
            weights: vec![vec![1, 2, 3, 4], vec![5, 6]],
        }
    }

    #[test]
    fn delta_roundtrips() {
        let d = sample();
        let bytes = d.to_bytes();
        let back = Delta::from_bytes(&bytes).unwrap();
        assert_eq!(back, d);
        assert_eq!(back.to_bytes(), bytes, "fixed point");
        assert_eq!(Delta::sniff_base(&bytes), Some(d.base_content));
        assert_eq!(Delta::sniff_base(b"MINISArt........"), None);
    }

    #[test]
    fn delta_tampering_detected() {
        let bytes = sample().to_bytes();
        let mut bad = bytes.clone();
        bad[bytes.len() / 2] ^= 1;
        assert!(matches!(Delta::from_bytes(&bad), Err(RegistryError::Corrupt(_))));
        assert!(Delta::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut v = bytes.clone();
        v[8] = 0x7f; // version byte
        let body = v.len() - 8;
        let ck = fnv64(&v[..body]).to_le_bytes();
        v[body..].copy_from_slice(&ck);
        assert!(matches!(Delta::from_bytes(&v), Err(RegistryError::Corrupt(_))));
    }
}
