//! Storage backends for the artifact registry: a flat keyspace of
//! `(blob, meta)` pairs behind the backend-agnostic [`RegistryBackend`]
//! trait (the mirage KV-backend pattern — the registry's logic never knows
//! whether it is talking to a directory, a test map, or a future object
//! store).

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::RegistryError;

/// A flat key → (blob, JSON metadata) store. Keys are registry key strings
/// (`<content:016x>-<arch:016x>`); implementations must be safe for
/// concurrent `put`/`get`/`delete` from many threads **and** processes:
/// a `get` racing a `put` or `delete` of the same key returns either the
/// complete old state, the complete new state, or a miss — never torn
/// bytes.
pub trait RegistryBackend: Send + Sync {
    /// Store a blob and its metadata record under `key` (overwriting both
    /// atomically with respect to readers).
    fn put(&self, key: &str, blob: &[u8], meta: &str) -> Result<(), RegistryError>;
    /// The blob under `key`; `Ok(None)` is the typed miss.
    fn get(&self, key: &str) -> Result<Option<std::sync::Arc<[u8]>>, RegistryError>;
    /// The metadata record under `key`; `Ok(None)` is the typed miss.
    fn meta(&self, key: &str) -> Result<Option<String>, RegistryError>;
    /// Remove `key`; `Ok(false)` if it was not present.
    fn delete(&self, key: &str) -> Result<bool, RegistryError>;
    /// Every key currently present, in unspecified order.
    fn list(&self) -> Result<Vec<String>, RegistryError>;
    /// Human-readable location for error messages and `Debug`.
    fn describe(&self) -> String;
}

/// Registry keys double as file names, so they must stay inside the store
/// directory: lowercase hex plus the `-` separator only.
fn check_key(key: &str) -> Result<(), RegistryError> {
    let ok = !key.is_empty()
        && key.len() <= 64
        && key.chars().all(|c| c.is_ascii_hexdigit() || c == '-');
    if ok {
        Ok(())
    } else {
        Err(RegistryError::Corrupt(format!("malformed registry key {key:?}")))
    }
}

/// Monotonic discriminator for temp-file names, so concurrent writers in
/// one process never collide on the same temp path.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// On-disk backend: `<root>/<key>.blob` + `<root>/<key>.json`. Writes go
/// through a temp file in the same directory followed by `rename`, which
/// is atomic on POSIX — readers see the old blob or the new one, never a
/// partial write. `put` of the same key is idempotent by construction
/// (content-addressed keys ⇒ same bytes), so racing writers are harmless.
pub struct DirBackend {
    root: PathBuf,
}

impl DirBackend {
    /// Open (creating if needed) a registry directory.
    pub fn open(root: &Path) -> Result<Self, RegistryError> {
        fs::create_dir_all(root)
            .map_err(|e| RegistryError::Io(format!("{}: {e}", root.display())))?;
        Ok(Self { root: root.to_path_buf() })
    }

    fn blob_path(&self, key: &str) -> PathBuf {
        self.root.join(format!("{key}.blob"))
    }

    fn meta_path(&self, key: &str) -> PathBuf {
        self.root.join(format!("{key}.json"))
    }

    /// Atomic write: temp file + rename into place.
    fn write_atomic(&self, target: &Path, bytes: &[u8]) -> Result<(), RegistryError> {
        let tmp = self.root.join(format!(
            ".tmp.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let io = |e: std::io::Error| RegistryError::Io(format!("{}: {e}", target.display()));
        fs::write(&tmp, bytes).map_err(&io)?;
        fs::rename(&tmp, target).map_err(|e| {
            fs::remove_file(&tmp).ok();
            io(e)
        })
    }

    /// A read that treats NotFound as the typed miss.
    fn read_opt(path: &Path) -> Result<Option<Vec<u8>>, RegistryError> {
        match fs::read(path) {
            Ok(b) => Ok(Some(b)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(RegistryError::Io(format!("{}: {e}", path.display()))),
        }
    }
}

impl RegistryBackend for DirBackend {
    fn put(&self, key: &str, blob: &[u8], meta: &str) -> Result<(), RegistryError> {
        check_key(key)?;
        // Blob first, meta second: a reader that sees the meta record can
        // rely on the blob already being in place.
        self.write_atomic(&self.blob_path(key), blob)?;
        self.write_atomic(&self.meta_path(key), meta.as_bytes())
    }

    fn get(&self, key: &str) -> Result<Option<std::sync::Arc<[u8]>>, RegistryError> {
        check_key(key)?;
        Ok(Self::read_opt(&self.blob_path(key))?.map(Into::into))
    }

    fn meta(&self, key: &str) -> Result<Option<String>, RegistryError> {
        check_key(key)?;
        match Self::read_opt(&self.meta_path(key))? {
            None => Ok(None),
            Some(b) => String::from_utf8(b)
                .map(Some)
                .map_err(|_| RegistryError::Corrupt(format!("non-UTF8 metadata for {key}"))),
        }
    }

    fn delete(&self, key: &str) -> Result<bool, RegistryError> {
        check_key(key)?;
        // Meta first (the announcement), blob second; either may already be
        // gone under a racing delete — NotFound is not an error here.
        let gone = |e: &std::io::Error| e.kind() == std::io::ErrorKind::NotFound;
        let meta = match fs::remove_file(self.meta_path(key)) {
            Ok(()) => true,
            Err(e) if gone(&e) => false,
            Err(e) => return Err(RegistryError::Io(format!("{key}: {e}"))),
        };
        let blob = match fs::remove_file(self.blob_path(key)) {
            Ok(()) => true,
            Err(e) if gone(&e) => false,
            Err(e) => return Err(RegistryError::Io(format!("{key}: {e}"))),
        };
        Ok(meta || blob)
    }

    fn list(&self) -> Result<Vec<String>, RegistryError> {
        let rd = fs::read_dir(&self.root)
            .map_err(|e| RegistryError::Io(format!("{}: {e}", self.root.display())))?;
        let mut keys = Vec::new();
        for entry in rd {
            let entry = entry.map_err(|e| RegistryError::Io(e.to_string()))?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(key) = name.strip_suffix(".blob") {
                if check_key(key).is_ok() {
                    keys.push(key.to_string());
                }
            }
        }
        keys.sort();
        Ok(keys)
    }

    fn describe(&self) -> String {
        self.root.display().to_string()
    }
}

/// In-memory backend for tests and ephemeral registries.
#[derive(Default)]
pub struct MemBackend {
    entries: Mutex<HashMap<String, (std::sync::Arc<[u8]>, String)>>,
}

impl MemBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RegistryBackend for MemBackend {
    fn put(&self, key: &str, blob: &[u8], meta: &str) -> Result<(), RegistryError> {
        check_key(key)?;
        self.entries
            .lock()
            .unwrap()
            .insert(key.to_string(), (blob.to_vec().into(), meta.to_string()));
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<std::sync::Arc<[u8]>>, RegistryError> {
        check_key(key)?;
        Ok(self.entries.lock().unwrap().get(key).map(|(b, _)| b.clone()))
    }

    fn meta(&self, key: &str) -> Result<Option<String>, RegistryError> {
        check_key(key)?;
        Ok(self.entries.lock().unwrap().get(key).map(|(_, m)| m.clone()))
    }

    fn delete(&self, key: &str) -> Result<bool, RegistryError> {
        check_key(key)?;
        Ok(self.entries.lock().unwrap().remove(key).is_some())
    }

    fn list(&self) -> Result<Vec<String>, RegistryError> {
        let mut keys: Vec<String> = self.entries.lock().unwrap().keys().cloned().collect();
        keys.sort();
        Ok(keys)
    }

    fn describe(&self) -> String {
        "mem".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("minisa_reg_{tag}_{}", std::process::id()))
    }

    #[test]
    fn dir_backend_roundtrip_delete_list() {
        let root = tmp_root("rt");
        std::fs::remove_dir_all(&root).ok();
        let b = DirBackend::open(&root).unwrap();
        let key = "00000000000000aa-00000000000000bb";
        assert!(b.get(key).unwrap().is_none(), "miss is typed, not an error");
        b.put(key, &[1, 2, 3], "{\"kind\":\"full\"}").unwrap();
        assert_eq!(&*b.get(key).unwrap().unwrap(), &[1, 2, 3]);
        assert_eq!(b.meta(key).unwrap().unwrap(), "{\"kind\":\"full\"}");
        assert_eq!(b.list().unwrap(), vec![key.to_string()]);
        assert!(b.delete(key).unwrap());
        assert!(!b.delete(key).unwrap(), "second delete is a clean no-op");
        assert!(b.get(key).unwrap().is_none());
        assert!(b.list().unwrap().is_empty());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn keys_that_escape_the_directory_are_rejected() {
        let root = tmp_root("esc");
        std::fs::remove_dir_all(&root).ok();
        let b = DirBackend::open(&root).unwrap();
        for bad in ["../evil", "a/b", "", "KEY WITH SPACE", "zz..zz"] {
            assert!(b.put(bad, &[0], "{}").is_err(), "{bad:?} must be rejected");
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn overwrite_is_atomic_for_readers() {
        // Single-threaded sanity of the rename path: after overwrite the
        // new bytes are visible in full (the multi-threaded race is in
        // tests/registry.rs).
        let root = tmp_root("ow");
        std::fs::remove_dir_all(&root).ok();
        let b = DirBackend::open(&root).unwrap();
        let key = "0000000000000001-0000000000000002";
        b.put(key, &[0u8; 64], "{}").unwrap();
        b.put(key, &[7u8; 64], "{}").unwrap();
        assert_eq!(&*b.get(key).unwrap().unwrap(), &[7u8; 64][..]);
        std::fs::remove_dir_all(&root).ok();
    }
}
