//! Content-addressed **artifact registry**: the persistent store and
//! deployment layer over `.minisa` containers (docs/REGISTRY.md).
//!
//! The paper's compiled programs are small and immutable (the encoded
//! trace *is* the artifact — 35×–4·10⁵× less instruction traffic than
//! micro-control, Fig. 12), which makes them ideal content-addressed
//! objects: the registry keys every blob by
//! `(content_hash, arch_fingerprint)` where the content hash is
//! [`fnv64`](crate::util::fnv64) over the canonical container bytes —
//! the same hash the container's own checksum and the arch fingerprint
//! already use. Every `get` re-verifies the content hash against the key,
//! so a corrupt or swapped blob is a typed error, never a served program.
//!
//! Pieces:
//!
//! * [`RegistryBackend`] — flat `put/get/delete/list` keyspace with JSON
//!   metadata alongside blobs (the mirage KV-backend pattern);
//!   [`DirBackend`] is the on-disk implementation (atomic tmp+rename
//!   writes), [`MemBackend`] the in-memory one.
//! * [`Delta`] — weights-only containers for the fine-tune-redeploy case:
//!   the stored base's trace/decisions are reused and
//!   [`Registry::resolve`]/[`Registry::get`] chases the base hash and
//!   re-verifies the **composed** checksum, so a delta's key is provably
//!   the content hash of the artifact a full recompile would produce.
//! * [`ProgramCache`] — a capacity-bounded LRU of loaded programs shared
//!   across sessions and fleet devices; a hit hands out `Arc`s to one
//!   decoded weight buffer (zero-copy, pointer-identity provable).
//! * `gc`/`verify`/`diff`/`list` — the operational surface, exposed by the
//!   `registry` CLI subcommand.

pub mod backend;
pub mod cache;
pub mod delta;

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::Path;
use std::sync::Arc;

use crate::arith::ElemType;
use crate::artifact::{
    fnv64, Artifact, ArtifactCheck, ArtifactError, WeightsPayload,
};
use crate::coordinator::serve::WordWeights;
use crate::program::Program;

pub use backend::{DirBackend, MemBackend, RegistryBackend};
pub use cache::{CacheStats, LoadedProgram, LoadedWeights, ProgramCache};
pub use delta::Delta;

/// Default [`ProgramCache`] capacity for [`Registry::open_dir`].
pub const DEFAULT_CACHE_CAPACITY: usize = 8;

/// Delta chains may nest (a delta of a delta); resolution follows base
/// links at most this deep before declaring the store corrupt.
const MAX_DELTA_DEPTH: usize = 8;

/// A registry address: content hash of the canonical artifact bytes plus
/// the arch fingerprint the stream was encoded for. String form (file
/// names, CLI): `<content:016x>-<arch:016x>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegistryKey {
    pub content: u64,
    pub arch: u64,
}

impl RegistryKey {
    /// The key of an artifact, together with the canonical bytes it was
    /// computed over (so callers hash and serialize exactly once).
    pub fn of(art: &Artifact) -> (RegistryKey, Vec<u8>) {
        let bytes = art.to_bytes();
        let key = RegistryKey { content: fnv64(&bytes), arch: art.fingerprint() };
        (key, bytes)
    }

    /// Parse the canonical `<content:016x>-<arch:016x>` form.
    pub fn parse(s: &str) -> Option<RegistryKey> {
        let (c, a) = s.split_once('-')?;
        if c.len() != 16 || a.len() != 16 {
            return None;
        }
        Some(RegistryKey {
            content: u64::from_str_radix(c, 16).ok()?,
            arch: u64::from_str_radix(a, 16).ok()?,
        })
    }
}

impl fmt::Display for RegistryKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}-{:016x}", self.content, self.arch)
    }
}

/// Everything that can go wrong talking to a registry.
#[derive(Debug, Clone, PartialEq)]
pub enum RegistryError {
    /// The key is not in the store — the *typed miss* (a gc'd or
    /// never-put key), never a panic.
    Miss(String),
    /// A blob or metadata record that cannot be trusted: content hash
    /// mismatch, undecodable container, malformed key.
    Corrupt(String),
    /// A delta whose base (or a link in its base chain) is gone.
    Dangling { key: String, base: String },
    /// A name/prefix lookup matched more than one key.
    Ambiguous(String),
    /// The artifact under this key has no weights payload, so it cannot be
    /// loaded into a serving session.
    NoPayload(String),
    /// Container-level failure surfaced while parsing or composing.
    Artifact(ArtifactError),
    /// Filesystem / backend failure.
    Io(String),
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::Miss(k) => write!(f, "registry miss: {k} not in store"),
            RegistryError::Corrupt(m) => write!(f, "registry corrupt: {m}"),
            RegistryError::Dangling { key, base } => {
                write!(f, "dangling delta {key}: base {base} not in store")
            }
            RegistryError::Ambiguous(m) => write!(f, "ambiguous registry lookup: {m}"),
            RegistryError::NoPayload(k) => {
                write!(f, "artifact {k} has no weights payload (not servable)")
            }
            RegistryError::Artifact(e) => write!(f, "artifact: {e}"),
            RegistryError::Io(m) => write!(f, "registry io: {m}"),
        }
    }
}

impl std::error::Error for RegistryError {}

impl From<ArtifactError> for RegistryError {
    fn from(e: ArtifactError) -> Self {
        RegistryError::Artifact(e)
    }
}

/// What [`Registry::load`] did to satisfy a request — the server folds
/// this into `registry_{hits,misses,evictions}_total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheOutcome {
    /// Served from the shared program cache (no blob read, no decode).
    pub hit: bool,
    /// LRU entries evicted by the insert on a miss.
    pub evicted: u64,
}

/// One row of [`Registry::list`].
#[derive(Debug, Clone, PartialEq)]
pub struct RegistryEntry {
    pub key: RegistryKey,
    /// `"full"` or `"delta"`.
    pub kind: &'static str,
    /// Model name recorded at put time (first chain layer's name).
    pub model: String,
    pub blob_bytes: usize,
    /// Immediate base content hash for deltas.
    pub base: Option<u64>,
}

/// Result of a [`Registry::gc`] sweep.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GcReport {
    pub kept: Vec<RegistryKey>,
    pub deleted: Vec<RegistryKey>,
}

/// The registry: a [`RegistryBackend`] plus the shared [`ProgramCache`].
pub struct Registry {
    backend: Box<dyn RegistryBackend>,
    cache: ProgramCache,
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Registry({}, {:?})", self.backend.describe(), self.cache.stats())
    }
}

impl Registry {
    pub fn new(backend: Box<dyn RegistryBackend>, cache_capacity: usize) -> Self {
        Self { backend, cache: ProgramCache::new(cache_capacity) }
    }

    /// Open (creating if needed) an on-disk registry with the default
    /// program-cache capacity.
    pub fn open_dir(root: &Path) -> Result<Self, RegistryError> {
        Ok(Self::new(Box::new(DirBackend::open(root)?), DEFAULT_CACHE_CAPACITY))
    }

    /// Shared program-cache statistics (hits/misses/evictions/len).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Store a full artifact under its content address. Idempotent: the
    /// key is a pure function of the bytes, and re-putting the same
    /// content rewrites identical files.
    pub fn put(&self, art: &Artifact) -> Result<RegistryKey, RegistryError> {
        let (key, bytes) = RegistryKey::of(art);
        let meta = meta_json(&key, "full", &model_name(art), art.chain.layers.len(), bytes.len(), None);
        self.backend.put(&key.to_string(), &bytes, &meta)?;
        // A re-put after gc must not serve a stale cached program.
        self.cache.invalidate(&key.to_string());
        Ok(key)
    }

    /// Store a weights-only delta against `base`: the composed artifact
    /// (base trace/decisions + `weights`) is computed here so the returned
    /// key is the content hash a full recompile of the same chain with
    /// these weights would produce — but only the weights are stored.
    pub fn put_delta(
        &self,
        base: RegistryKey,
        elem: ElemType,
        weights: Vec<Vec<u64>>,
    ) -> Result<RegistryKey, RegistryError> {
        let base_art = self.get(base)?;
        let composed = compose(&base_art, elem, &weights)?;
        // Only the hash of the composed form is kept; the blob stored below
        // is the small weights-only delta.
        let (key, _) = RegistryKey::of(&composed);
        debug_assert_eq!(key.arch, base.arch, "composition never changes the arch section");
        let d = Delta {
            base_content: base.content,
            arch: base.arch,
            composed_content: key.content,
            elem,
            weights,
        };
        let blob = d.to_bytes();
        let meta = meta_json(
            &key,
            "delta",
            &model_name(&composed),
            composed.chain.layers.len(),
            blob.len(),
            Some(base.content),
        );
        self.backend.put(&key.to_string(), &blob, &meta)?;
        self.cache.invalidate(&key.to_string());
        Ok(key)
    }

    /// Fetch and fully verify the artifact under `key`. Full blobs are
    /// hash-checked against the key and parsed zero-copy
    /// ([`Artifact::from_shared`]); deltas are resolved against their base
    /// chain and the **composed** bytes re-hashed against the key. A
    /// missing key is the typed [`RegistryError::Miss`].
    pub fn get(&self, key: RegistryKey) -> Result<Artifact, RegistryError> {
        self.get_at_depth(key, 0)
    }

    fn get_at_depth(&self, key: RegistryKey, depth: usize) -> Result<Artifact, RegistryError> {
        if depth > MAX_DELTA_DEPTH {
            return Err(RegistryError::Corrupt(format!(
                "delta chain under {key} deeper than {MAX_DELTA_DEPTH}"
            )));
        }
        let ks = key.to_string();
        let blob = self.backend.get(&ks)?.ok_or(RegistryError::Miss(ks.clone()))?;
        if blob.len() >= 8 && blob[..8] == crate::artifact::MAGIC {
            if fnv64(&blob) != key.content {
                return Err(RegistryError::Corrupt(format!(
                    "{ks}: blob bytes hash to {:016x}, key says {:016x}",
                    fnv64(&blob),
                    key.content
                )));
            }
            let art = Artifact::from_shared(blob)?;
            if art.fingerprint() != key.arch {
                return Err(RegistryError::Corrupt(format!(
                    "{ks}: arch fingerprint {:016x} does not match key",
                    art.fingerprint()
                )));
            }
            Ok(art)
        } else {
            self.resolve(key, &blob, depth)
        }
    }

    /// Resolve a delta blob: chase the base hash, compose, and re-verify
    /// the composed checksum against the key.
    fn resolve(
        &self,
        key: RegistryKey,
        blob: &[u8],
        depth: usize,
    ) -> Result<Artifact, RegistryError> {
        let ks = key.to_string();
        let d = Delta::from_bytes(blob)?;
        if d.composed_content != key.content || d.arch != key.arch {
            return Err(RegistryError::Corrupt(format!(
                "{ks}: delta header addresses {:016x}-{:016x}",
                d.composed_content, d.arch
            )));
        }
        let base_key = RegistryKey { content: d.base_content, arch: d.arch };
        let base = match self.get_at_depth(base_key, depth + 1) {
            Err(RegistryError::Miss(_)) => {
                return Err(RegistryError::Dangling { key: ks, base: base_key.to_string() })
            }
            r => r?,
        };
        let composed = compose(&base, d.elem, &d.weights)?;
        let bytes = composed.to_bytes();
        if fnv64(&bytes) != key.content {
            return Err(RegistryError::Corrupt(format!(
                "{ks}: composed artifact hashes to {:016x}, key says {:016x}",
                fnv64(&bytes),
                key.content
            )));
        }
        Ok(composed)
    }

    /// Load `key` into its serving form through the shared
    /// [`ProgramCache`]: a hit returns the cached `Arc`s (one program, one
    /// weight buffer, shared by every caller); a miss does the full
    /// verified get + decode and populates the cache.
    pub fn load(&self, key: RegistryKey) -> Result<(Arc<LoadedProgram>, CacheOutcome), RegistryError> {
        let ks = key.to_string();
        if let Some(hit) = self.cache.get(&ks) {
            return Ok((hit, CacheOutcome { hit: true, evicted: 0 }));
        }
        let art = self.get(key)?;
        let payload = art.payload.as_ref().ok_or(RegistryError::NoPayload(ks.clone()))?;
        let elem = payload.elem;
        let weights = if elem == ElemType::F32 {
            LoadedWeights::F32(Arc::new(
                payload.weights.iter().map(|m| m.decode::<f32>()).collect(),
            ))
        } else {
            LoadedWeights::Words(Arc::new(WordWeights::from_matrices(&payload.weights, elem)))
        };
        let program = Program::from_artifact(&art)?;
        let loaded =
            Arc::new(LoadedProgram { key, program: Arc::new(program), elem, weights });
        let evicted = self.cache.insert(&ks, Arc::clone(&loaded));
        Ok((loaded, CacheOutcome { hit: false, evicted }))
    }

    /// Every entry in the store (sorted by key string), with kind and
    /// metadata resolved.
    pub fn list(&self) -> Result<Vec<RegistryEntry>, RegistryError> {
        let mut out = Vec::new();
        for ks in self.backend.list()? {
            let Some(key) = RegistryKey::parse(&ks) else { continue };
            // A concurrent gc may remove the blob between list and get —
            // skip vanished keys rather than failing the whole listing.
            let Some(blob) = self.backend.get(&ks)? else { continue };
            let base = Delta::sniff_base(&blob);
            let kind = if base.is_some() { "delta" } else { "full" };
            let model = self
                .backend
                .meta(&ks)?
                .and_then(|m| json_str_field(&m, "model"))
                .unwrap_or_default();
            out.push(RegistryEntry { key, kind, model, blob_bytes: blob.len(), base });
        }
        Ok(out)
    }

    /// Resolve a user-facing spec to one key. Accepted forms, in order:
    /// the exact `<content>-<arch>` string; a prefix of the content hash
    /// (≥ 4 hex digits); a model name recorded at put time. With
    /// `eligible` set (the fleet's device arch fingerprints), only keys an
    /// eligible device can execute are considered, and a name that exists
    /// for several eligible arch variants resolves to the variant of the
    /// *earliest* eligible fingerprint (deterministic cross-arch
    /// placement); without it, multiple matches are a typed
    /// [`RegistryError::Ambiguous`].
    pub fn find(
        &self,
        spec: &str,
        eligible: Option<&[u64]>,
    ) -> Result<RegistryKey, RegistryError> {
        if let Some(key) = RegistryKey::parse(spec) {
            return match self.backend.get(&key.to_string())? {
                Some(_) => Ok(key),
                None => Err(RegistryError::Miss(spec.to_string())),
            };
        }
        let entries = self.list()?;
        let ok = |k: &RegistryKey| eligible.map_or(true, |fps| fps.contains(&k.arch));
        let spec_lc = spec.to_ascii_lowercase();
        let by_prefix: Vec<RegistryKey> = if spec_lc.len() >= 4
            && spec_lc.chars().all(|c| c.is_ascii_hexdigit())
        {
            entries
                .iter()
                .map(|e| e.key)
                .filter(|k| ok(k) && format!("{:016x}", k.content).starts_with(&spec_lc))
                .collect()
        } else {
            Vec::new()
        };
        let mut cands = by_prefix;
        if cands.is_empty() {
            cands = entries
                .iter()
                .filter(|e| e.model == spec && ok(&e.key))
                .map(|e| e.key)
                .collect();
        }
        match cands.len() {
            0 => Err(RegistryError::Miss(spec.to_string())),
            1 => Ok(cands[0]),
            _ => {
                if let Some(fps) = eligible {
                    // The fleet can run several variants: pick the variant
                    // of the earliest eligible fingerprint, content as the
                    // tie-break, so resolution is deterministic.
                    cands.sort_by_key(|k| {
                        (fps.iter().position(|&f| f == k.arch).unwrap_or(usize::MAX), k.content)
                    });
                    Ok(cands[0])
                } else {
                    let list =
                        cands.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(", ");
                    Err(RegistryError::Ambiguous(format!("{spec} matches {list}")))
                }
            }
        }
    }

    /// Garbage-collect the store.
    ///
    /// Policy (docs/REGISTRY.md): **dangling deltas** — deltas whose base
    /// chain is broken — are always deleted; they can never resolve again.
    /// With an empty pin set nothing else is touched (the safe default:
    /// every resolvable blob stays). With pins, the live set is the pinned
    /// keys plus every base transitively reachable from them, and all
    /// other blobs are deleted.
    pub fn gc(&self, pins: &[RegistryKey]) -> Result<GcReport, RegistryError> {
        // Snapshot: content hash → (key, immediate base link).
        let mut present: HashMap<u64, (RegistryKey, Option<u64>)> = HashMap::new();
        for ks in self.backend.list()? {
            let Some(key) = RegistryKey::parse(&ks) else { continue };
            let Some(blob) = self.backend.get(&ks)? else { continue };
            present.insert(key.content, (key, Delta::sniff_base(&blob)));
        }
        // A delta resolves iff every base link exists and the chain
        // terminates at a full blob within the depth cap.
        let chain_ok = |start: u64| -> bool {
            let mut c = start;
            for _ in 0..=MAX_DELTA_DEPTH {
                match present.get(&c) {
                    None => return false,
                    Some((_, None)) => return true,
                    Some((_, Some(base))) => c = *base,
                }
            }
            false
        };
        let mut live: HashSet<u64> = HashSet::new();
        if pins.is_empty() {
            for (&c, (_, base)) in &present {
                if base.is_none() || chain_ok(c) {
                    live.insert(c);
                }
            }
        } else {
            for pin in pins {
                let mut c = pin.content;
                for _ in 0..=MAX_DELTA_DEPTH {
                    match present.get(&c) {
                        None => break,
                        Some((_, base)) => {
                            live.insert(c);
                            match base {
                                None => break,
                                Some(b) => c = *b,
                            }
                        }
                    }
                }
            }
            // Even under pins, a broken chain can never resolve — its
            // members are dead regardless of pinning.
            live.retain(|&c| chain_ok(c));
        }
        let mut report = GcReport::default();
        for (&c, &(key, _)) in &present {
            if live.contains(&c) {
                report.kept.push(key);
            } else {
                self.backend.delete(&key.to_string())?;
                self.cache.invalidate(&key.to_string());
                report.deleted.push(key);
            }
        }
        report.kept.sort();
        report.deleted.sort();
        Ok(report)
    }

    /// Verify every blob in the store: content hash against key, container
    /// checksums, delta resolution, and the stream round-trip proof
    /// ([`Artifact::verify`]).
    pub fn verify_all(&self) -> Result<Vec<(RegistryKey, Result<ArtifactCheck, RegistryError>)>, RegistryError> {
        let mut out = Vec::new();
        for ks in self.backend.list()? {
            let Some(key) = RegistryKey::parse(&ks) else { continue };
            let r = self.get(key).and_then(|a| a.verify().map_err(RegistryError::Artifact));
            out.push((key, r));
        }
        Ok(out)
    }

    /// Remove one key (blob + metadata); `Ok(false)` if absent.
    pub fn delete(&self, key: RegistryKey) -> Result<bool, RegistryError> {
        self.cache.invalidate(&key.to_string());
        self.backend.delete(&key.to_string())
    }
}

/// Human-readable structural diff between two artifacts — arch, per-layer
/// dims/mapping decisions, instruction-class counts, payload. One line per
/// difference; empty means byte-compatible structure (the containers may
/// still differ in weights — weight *values* are deliberately not diffed,
/// only their shape and element type).
pub fn diff(a: &Artifact, b: &Artifact) -> Vec<String> {
    let mut out = Vec::new();
    if a.cfg != b.cfg {
        out.push(format!(
            "arch: {} ({:016x}) vs {} ({:016x})",
            a.cfg.name(),
            a.fingerprint(),
            b.cfg.name(),
            b.fingerprint()
        ));
    }
    let (la, lb) = (a.chain.layers.len(), b.chain.layers.len());
    if la != lb {
        out.push(format!("layers: {la} vs {lb}"));
    }
    for (i, (ga, gb)) in a.chain.layers.iter().zip(&b.chain.layers).enumerate() {
        if (ga.m, ga.k, ga.n) != (gb.m, gb.k, gb.n) {
            out.push(format!(
                "layer {i}: {}×{}×{} vs {}×{}×{}",
                ga.m, ga.k, ga.n, gb.m, gb.k, gb.n
            ));
        }
    }
    for (i, (da, db)) in a.decision.per_layer.iter().zip(&b.decision.per_layer).enumerate() {
        // Formatted comparison: one stable rendering of the mapping choice
        // covers every field without requiring PartialEq on each.
        let render = |d: &crate::mapper::Decision| {
            format!(
                "df={:?} vn={} tile=({},{},{}) nbc={} dup={} orders=({},{},{})",
                d.choice.df,
                d.choice.vn,
                d.choice.m_t,
                d.choice.k_t,
                d.choice.n_t,
                d.choice.nbc,
                d.choice.dup,
                d.i_order,
                d.w_order,
                d.o_order,
            )
        };
        let (ra, rb) = (render(da), render(db));
        if ra != rb {
            out.push(format!("decision {i}: {ra} vs {rb}"));
        }
    }
    match (a.verify(), b.verify()) {
        (Ok(ca), Ok(cb)) => {
            if ca.classes != cb.classes || ca.insts != cb.insts || ca.trace_bytes != cb.trace_bytes
            {
                out.push(format!(
                    "trace: {} insts / {} B, classes {:?} vs {} insts / {} B, classes {:?}",
                    ca.insts, ca.trace_bytes, ca.classes, cb.insts, cb.trace_bytes, cb.classes
                ));
            }
        }
        (ra, rb) => {
            if let Err(e) = ra {
                out.push(format!("left: verify failed: {e}"));
            }
            if let Err(e) = rb {
                out.push(format!("right: verify failed: {e}"));
            }
        }
    }
    match (&a.payload, &b.payload) {
        (Some(pa), Some(pb)) => {
            if pa.elem != pb.elem {
                out.push(format!("payload elem: {} vs {}", pa.elem, pb.elem));
            }
            let wa: usize = pa.weights.iter().map(|m| m.len()).sum();
            let wb: usize = pb.weights.iter().map(|m| m.len()).sum();
            if wa != wb {
                out.push(format!("payload words: {wa} vs {wb}"));
            } else if pa.weights != pb.weights {
                out.push(format!("payload: same shape ({wa} words), different weight values"));
            }
        }
        (Some(_), None) => out.push("payload: present vs none".to_string()),
        (None, Some(_)) => out.push("payload: none vs present".to_string()),
        (None, None) => {}
    }
    out
}

/// Compose a base artifact with replacement weights (the delta semantics):
/// everything but the payload is reused verbatim.
fn compose(
    base: &Artifact,
    elem: ElemType,
    weights: &[Vec<u64>],
) -> Result<Artifact, RegistryError> {
    let payload = WeightsPayload::owned(elem, weights.to_vec());
    crate::artifact::validate_payload_dims(&base.chain, &payload.weights)?;
    let mut composed = base.clone();
    composed.payload = Some(payload);
    Ok(composed)
}

/// Model name recorded in metadata: the first chain layer's name (layer
/// names share the chain's prefix by construction — `Chain::mlp("m", ..)`
/// names layers `m_l0`, `m_l1`, …).
fn model_name(art: &Artifact) -> String {
    let first = &art.chain.layers[0].name;
    first.split("_l").next().unwrap_or(first).to_string()
}

/// Hand-rolled metadata record (std-only JSON writing; the reader side
/// only ever extracts flat string fields via [`json_str_field`]).
fn meta_json(
    key: &RegistryKey,
    kind: &str,
    model: &str,
    layers: usize,
    blob_bytes: usize,
    base: Option<u64>,
) -> String {
    let base = base.map(|b| format!("{b:016x}")).unwrap_or_default();
    format!(
        "{{\"key\":\"{key}\",\"kind\":\"{kind}\",\"model\":\"{}\",\"layers\":{layers},\
         \"blob_bytes\":{blob_bytes},\"base\":\"{base}\",\"content\":\"{:016x}\",\
         \"arch\":\"{:016x}\"}}",
        model.replace(['"', '\\'], "_"),
        key.content,
        key.arch,
    )
}

/// Extract a flat string field from a metadata record. Only handles the
/// escape-free strings [`meta_json`] writes (names are sanitized at write
/// time) — not a general JSON parser.
fn json_str_field(meta: &str, field: &str) -> Option<String> {
    let tag = format!("\"{field}\":\"");
    let at = meta.find(&tag)? + tag.len();
    let rest = &meta[at..];
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::artifact::Compiler;
    use crate::mapper::chain::Chain;
    use crate::util::Lcg;

    fn sample_weights(chain: &Chain, elem: ElemType, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Lcg::new(seed);
        chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect()
    }

    fn compile(cfg: &ArchConfig, chain: &Chain, elem: ElemType, seed: u64) -> Artifact {
        Compiler::new(cfg)
            .elem(elem)
            .weights(sample_weights(chain, elem, seed))
            .compile(chain)
            .unwrap()
    }

    fn mem_registry() -> Registry {
        Registry::new(Box::new(MemBackend::new()), 4)
    }

    #[test]
    fn put_get_verifies_content_address() {
        let reg = mem_registry();
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("m", 8, &[8, 8]);
        let art = compile(&cfg, &chain, ElemType::I32, 3);
        let key = reg.put(&art).unwrap();
        assert_eq!(key, reg.put(&art).unwrap(), "content addressing is idempotent");
        let back = reg.get(key).unwrap();
        assert_eq!(back, art);
        assert_eq!(back.to_bytes(), art.to_bytes());
        // A key that was never put is the typed miss.
        let missing = RegistryKey { content: 0xdead, arch: key.arch };
        assert!(matches!(reg.get(missing), Err(RegistryError::Miss(_))));
    }

    #[test]
    fn corrupt_blob_is_detected_on_get() {
        let reg = mem_registry();
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("m", 8, &[8, 8]);
        let art = compile(&cfg, &chain, ElemType::I32, 4);
        let key = reg.put(&art).unwrap();
        // Overwrite the blob under the same key with different (valid
        // container) bytes: the content hash no longer matches the key.
        let other = compile(&cfg, &chain, ElemType::I32, 5);
        reg.backend.put(&key.to_string(), &other.to_bytes(), "{}").unwrap();
        assert!(matches!(reg.get(key), Err(RegistryError::Corrupt(_))));
    }

    #[test]
    fn delta_resolves_and_composed_matches_full_recompile() {
        let reg = mem_registry();
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("m", 8, &[8, 8]);
        let elem = ElemType::BabyBear;
        let base_art = compile(&cfg, &chain, elem, 10);
        let base = reg.put(&base_art).unwrap();
        let new_weights = sample_weights(&chain, elem, 11);
        let dkey = reg.put_delta(base, elem, new_weights.clone()).unwrap();
        assert_eq!(dkey.arch, base.arch);
        assert_ne!(dkey.content, base.content);
        // Resolution re-verifies the composed checksum…
        let composed = reg.get(dkey).unwrap();
        // …and the composed bytes are identical to a full recompile of the
        // same chain with the new weights (deterministic compiler).
        let full = Compiler::new(&cfg).elem(elem).weights(new_weights).compile(&chain).unwrap();
        assert_eq!(composed.to_bytes(), full.to_bytes(), "delta ≡ full recompile, byte-exact");
        // The stored delta blob is weights-only: much smaller than a full
        // container whose payload dominates… at these tiny sizes just
        // assert it parses as a delta.
        let blob = reg.backend.get(&dkey.to_string()).unwrap().unwrap();
        assert_eq!(Delta::sniff_base(&blob), Some(base.content));
    }

    #[test]
    fn dangling_delta_is_typed_and_gc_removes_it() {
        let reg = mem_registry();
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("m", 8, &[8, 8]);
        let elem = ElemType::I32;
        let base = reg.put(&compile(&cfg, &chain, elem, 1)).unwrap();
        let dkey = reg.put_delta(base, elem, sample_weights(&chain, elem, 2)).unwrap();
        reg.delete(base).unwrap();
        assert!(matches!(reg.get(dkey), Err(RegistryError::Dangling { .. })));
        let report = reg.gc(&[]).unwrap();
        assert_eq!(report.deleted, vec![dkey], "dangling delta swept");
        assert!(matches!(reg.get(dkey), Err(RegistryError::Miss(_))));
    }

    #[test]
    fn gc_with_pins_keeps_base_closure() {
        let reg = mem_registry();
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("m", 8, &[8, 8]);
        let elem = ElemType::I32;
        let base = reg.put(&compile(&cfg, &chain, elem, 1)).unwrap();
        let dkey = reg.put_delta(base, elem, sample_weights(&chain, elem, 2)).unwrap();
        let stray = reg.put(&compile(&cfg, &chain, elem, 9)).unwrap();
        let report = reg.gc(&[dkey]).unwrap();
        assert!(report.kept.contains(&dkey), "pinned delta kept");
        assert!(report.kept.contains(&base), "its base kept (live chain)");
        assert_eq!(report.deleted, vec![stray], "unpinned blob collected");
        assert!(reg.get(dkey).is_ok(), "the live chain still resolves after gc");
    }

    #[test]
    fn find_resolves_exact_prefix_name_and_eligibility() {
        let reg = mem_registry();
        let chain = Chain::mlp("modelx", 8, &[8, 8]);
        let elem = ElemType::I32;
        let a44 = compile(&ArchConfig::paper(4, 4), &chain, elem, 1);
        let a48 = compile(&ArchConfig::paper(4, 8), &chain, elem, 1);
        let k44 = reg.put(&a44).unwrap();
        let k48 = reg.put(&a48).unwrap();
        // Exact key string.
        assert_eq!(reg.find(&k44.to_string(), None).unwrap(), k44);
        // Content-hash prefix.
        let prefix = format!("{:016x}", k48.content)[..8].to_string();
        assert_eq!(reg.find(&prefix, None).unwrap(), k48);
        // Name without eligibility: ambiguous across the two arch variants.
        assert!(matches!(reg.find("modelx", None), Err(RegistryError::Ambiguous(_))));
        // Name with eligibility: picks the variant the fleet can run.
        assert_eq!(reg.find("modelx", Some(&[k48.arch])).unwrap(), k48);
        assert_eq!(reg.find("modelx", Some(&[k44.arch, k48.arch])).unwrap(), k44);
        // Eligibility excludes everything: typed miss.
        assert!(matches!(
            reg.find("modelx", Some(&[0x1234])),
            Err(RegistryError::Miss(_))
        ));
    }

    #[test]
    fn load_shares_one_allocation_across_callers() {
        let reg = mem_registry();
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("m", 8, &[8, 8]);
        let art = compile(&cfg, &chain, ElemType::Goldilocks, 6);
        let key = reg.put(&art).unwrap();
        let (a, oa) = reg.load(key).unwrap();
        let (b, ob) = reg.load(key).unwrap();
        let (c, oc) = reg.load(key).unwrap();
        assert!(!oa.hit && ob.hit && oc.hit);
        assert!(Arc::ptr_eq(&a, &b) && Arc::ptr_eq(&b, &c), "one loaded entry");
        assert!(Arc::ptr_eq(&a.program, &b.program), "one compiled program");
        let (LoadedWeights::Words(wa), LoadedWeights::Words(wc)) = (&a.weights, &c.weights)
        else {
            panic!("field-typed entry");
        };
        assert!(Arc::ptr_eq(wa, wc), "one decoded weight buffer across callers");
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses), (2, 1));
    }

    #[test]
    fn meta_json_roundtrips_fields() {
        let key = RegistryKey { content: 0xab, arch: 0xcd };
        let m = meta_json(&key, "full", "mlp_demo", 3, 128, None);
        assert_eq!(json_str_field(&m, "model").unwrap(), "mlp_demo");
        assert_eq!(json_str_field(&m, "kind").unwrap(), "full");
        assert_eq!(json_str_field(&m, "base").unwrap(), "");
        assert!(json_str_field(&m, "nope").is_none());
        // Quotes in a hostile model name are sanitized, not emitted.
        let hostile = meta_json(&key, "full", "a\"b", 1, 1, Some(7));
        assert_eq!(json_str_field(&hostile, "model").unwrap(), "a_b");
    }
}
