#!/usr/bin/env python3
"""Validate a --metrics-out telemetry snapshot against the checked-in schema.

Stdlib-only (CI runners have no pip). Usage:

    python3 tools/check_metrics.py <snapshot.json> [<schema.json>]

The snapshot is what `minisa serve|serve-model|loadgen --metrics-out` and
`minisa metrics --json` write (docs/OBSERVABILITY.md §Export formats); the
schema (default: tools/metrics_schema.json next to this script) pins the
metric catalog — required counters/gauges/histograms, the per-device gauge
name patterns, and the histogram field layout.

Checks, in order:
  1. document shape: schema version, counters/gauges/histograms maps
  2. every required counter present, integer, non-negative
  3. every required gauge present and numeric; every per-device gauge
     pattern matched by at least one name (dev0 always exists)
  4. every required histogram present with every required field, buckets
     well-formed ([lo, count] pairs, lo ascending, counts summing to
     `count`, min <= p50 <= p99 <= p999 <= max when non-empty)

Exit 0 when the snapshot conforms; exit 1 with one line per violation.
"""

import json
import os
import re
import sys


def fail(errors):
    for e in errors:
        print(f"check_metrics: {e}", file=sys.stderr)
    print(f"check_metrics: FAIL ({len(errors)} violation(s))", file=sys.stderr)
    sys.exit(1)


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_histogram(name, h, fields, errors):
    if not isinstance(h, dict):
        errors.append(f"histogram {name}: expected an object, got {type(h).__name__}")
        return
    for f in fields:
        if f not in h:
            errors.append(f"histogram {name}: missing field '{f}'")
    buckets = h.get("buckets")
    count = h.get("count")
    if not isinstance(count, int) or count < 0:
        errors.append(f"histogram {name}: count must be a non-negative integer, got {count!r}")
        return
    if not isinstance(buckets, list):
        errors.append(f"histogram {name}: buckets must be a list")
        return
    total, last_lo = 0, float("-inf")
    for i, b in enumerate(buckets):
        if not (isinstance(b, list) and len(b) == 2 and is_num(b[0]) and isinstance(b[1], int)):
            errors.append(f"histogram {name}: bucket[{i}] must be [lo, count], got {b!r}")
            return
        lo, n = b
        if lo <= last_lo:
            errors.append(f"histogram {name}: bucket lower bounds must ascend ({lo} after {last_lo})")
        if n <= 0:
            errors.append(f"histogram {name}: bucket[{i}] count must be positive (empty buckets are elided)")
        last_lo = lo
        total += n
    if total != count:
        errors.append(f"histogram {name}: bucket counts sum to {total}, count says {count}")
    if count > 0:
        keys = ("min", "p50", "p99", "p999", "max")
        qs = [(k, h.get(k)) for k in keys]
        if all(is_num(v) for _, v in qs):
            for (ka, a), (kb, b) in zip(qs, qs[1:]):
                if a > b:
                    errors.append(f"histogram {name}: {ka} ({a}) > {kb} ({b})")
        else:
            errors.append(f"histogram {name}: non-numeric quantile among {'/'.join(keys)}")


def main():
    if len(sys.argv) not in (2, 3):
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    snap_path = sys.argv[1]
    schema_path = (
        sys.argv[2]
        if len(sys.argv) == 3
        else os.path.join(os.path.dirname(os.path.abspath(__file__)), "metrics_schema.json")
    )
    if not os.path.exists(schema_path):
        fail(
            [
                f"schema file {schema_path} not found — it is checked in as "
                "tools/metrics_schema.json; pass its path explicitly if running "
                "from an unusual working directory"
            ]
        )
    try:
        with open(snap_path) as f:
            snap = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail([f"cannot read snapshot {snap_path}: {e}"])
    with open(schema_path) as f:
        schema = json.load(f)

    errors = []
    if snap.get("schema") != schema.get("schema_version"):
        errors.append(
            f"snapshot schema version {snap.get('schema')!r} != "
            f"expected {schema.get('schema_version')!r}"
        )
    counters = snap.get("counters")
    gauges = snap.get("gauges")
    histograms = snap.get("histograms")
    for fam, v in (("counters", counters), ("gauges", gauges), ("histograms", histograms)):
        if not isinstance(v, dict):
            errors.append(f"snapshot '{fam}' must be an object, got {type(v).__name__}")
    if errors:
        fail(errors)

    for name in schema.get("required_counters", []):
        v = counters.get(name)
        if v is None:
            errors.append(f"missing counter {name}")
        elif not isinstance(v, int) or v < 0:
            errors.append(f"counter {name} must be a non-negative integer, got {v!r}")

    for name in schema.get("required_gauges", []):
        v = gauges.get(name)
        if v is None:
            errors.append(f"missing gauge {name}")
        elif not is_num(v):
            errors.append(f"gauge {name} must be numeric, got {v!r}")
    for pat in schema.get("required_gauge_patterns", []):
        rx = re.compile(pat)
        if not any(rx.match(name) for name in gauges):
            errors.append(f"no gauge matches required pattern {pat}")

    fields = schema.get("histogram_fields", [])
    for name in schema.get("required_histograms", []):
        h = histograms.get(name)
        if h is None:
            errors.append(f"missing histogram {name}")
        else:
            check_histogram(name, h, fields, errors)

    if errors:
        fail(errors)
    print(
        f"check_metrics: OK — {len(counters)} counters, {len(gauges)} gauges, "
        f"{len(histograms)} histograms conform to {os.path.basename(schema_path)}"
    )


if __name__ == "__main__":
    main()
