#!/usr/bin/env python3
"""Bench regression gate for the BENCH_*.json logs (§Perf CI satellite).

Compares the throughput metrics of a freshly-emitted bench log against a
committed baseline and fails (exit 1) if any metric regresses by more than
the allowed fraction. Only *throughput* metrics are gated — names containing
``macs_per_s`` or ``rows_per_s`` (covering the ``_before``/``_after``
variants), where higher is better — because raw medians and speedup ratios
are too noisy on shared CI runners to block on individually.

Usage:
    bench_regression.py BASELINE.json FRESH.json [--max-regress 0.10]

Metrics present only in the fresh log (new benches) pass; metrics present
only in the baseline (renamed/removed benches) are reported as warnings so
a rename cannot silently drop coverage.

Stdlib only — the CI image needs nothing beyond python3.
"""

import argparse
import json
import sys

THROUGHPUT_MARKERS = ("macs_per_s", "rows_per_s")


def throughput_metrics(log):
    metrics = log.get("metrics", {})
    return {
        name: value
        for name, value in metrics.items()
        if any(m in name for m in THROUGHPUT_MARKERS) and isinstance(value, (int, float))
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("fresh", help="freshly-emitted BENCH_*.json")
    ap.add_argument(
        "--max-regress",
        type=float,
        default=0.10,
        help="maximum allowed fractional throughput drop (default 0.10 = 10%%)",
    )
    args = ap.parse_args()

    try:
        with open(args.baseline) as f:
            baseline = throughput_metrics(json.load(f))
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read baseline {args.baseline}: {e}", file=sys.stderr)
        return 1
    try:
        with open(args.fresh) as f:
            fresh = throughput_metrics(json.load(f))
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read fresh log {args.fresh}: {e}", file=sys.stderr)
        return 1

    if not baseline:
        print(
            f"warning: baseline {args.baseline} has no throughput metrics; nothing to gate"
        )
        return 0

    failures = []
    for name in sorted(baseline):
        base = baseline[name]
        if name not in fresh:
            print(f"warning: metric {name!r} missing from fresh log (renamed or removed?)")
            continue
        if base <= 0:
            continue  # degenerate baseline sample; cannot compute a ratio
        now = fresh[name]
        change = (now - base) / base
        status = "ok"
        if change < -args.max_regress:
            status = "REGRESSED"
            failures.append((name, base, now, change))
        print(f"  {name}: {base:.3f} -> {now:.3f} ({change:+.1%}) {status}")

    new = sorted(set(fresh) - set(baseline))
    for name in new:
        print(f"  {name}: (new) {fresh[name]:.3f}")

    if failures:
        print(
            f"\n{len(failures)} throughput metric(s) regressed more than "
            f"{args.max_regress:.0%}:",
            file=sys.stderr,
        )
        for name, base, now, change in failures:
            print(f"  {name}: {base:.3f} -> {now:.3f} ({change:+.1%})", file=sys.stderr)
        return 1
    print(f"\nall {len(baseline)} gated metrics within {args.max_regress:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
