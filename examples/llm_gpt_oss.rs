//! Domain example — GPT-oss-20B inference layers (§VI workloads), the
//! dynamic-operand case FEATHER+ was refined for: both operands arrive at
//! runtime, so FEATHER's pre-known-weight offline reorder does not apply.
//!
//! Builds the multi-layer MINISA trace for a 3-layer MLP slice of the
//! model, demonstrates the §IV-G2 consecutive-layer optimization (layer i's
//! SetOVNLayout doubles as layer i+1's SetIVNLayout), then serves batched
//! GEMM requests through the serving coordinator (PJRT runtime when
//! artifacts are available).
//!
//! ```sh
//! cargo run --release --example llm_gpt_oss
//! ```

use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::coordinator::serve::{spawn, NaiveExecutor, Request, TileExecutor};
use minisa::isa::inst::{Inst, LayoutInst};
use minisa::isa::Trace;
use minisa::mapper::search::{search, MapperOptions};
use minisa::mapper::lower_gemm;
use minisa::util::{percentile, Lcg};
use minisa::workloads::Gemm;

fn main() -> anyhow::Result<()> {
    let cfg = ArchConfig::paper(16, 64);
    // A GPT-oss-like MLP slice: 2880 → 5120 → 2880 (Tab. IV shapes), with a
    // short sequence so the example runs quickly.
    let layers = [
        Gemm::new("qkv_proj", "GPT-oss", 256, 2880, 5120),
        Gemm::new("mlp_down", "GPT-oss", 256, 5120, 2880),
        Gemm::new("lm_head_slice", "GPT-oss", 256, 2880, 2048),
    ];
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };

    // 1. Per-layer mapping + one fused multi-layer trace.
    let mut chain = Trace::new();
    let mut total_minisa = 0u64;
    let mut total_micro = 0u64;
    for g in &layers {
        let d = search(&cfg, g, &opts).ok_or_else(|| anyhow::anyhow!("no mapping for {g}"))?;
        let prog = lower_gemm(&cfg, g, &d.choice, d.i_order, d.w_order, d.o_order);
        println!(
            "{:<14} M={} K={} N={}: df {:?}, tile ({},{},{}), util {:.1}%, {} insts, {} B MINISA / {} B micro",
            g.name, g.m, g.k, g.n, d.choice.df, d.choice.m_t, d.choice.k_t, d.choice.n_t,
            d.report.utilization() * 100.0,
            prog.trace.len(),
            prog.minisa_bytes(),
            prog.micro_bytes(),
        );
        total_minisa += prog.minisa_bytes();
        total_micro += prog.micro_bytes();
        chain.begin_layer();
        // Splice the per-layer program into the chain trace.
        for inst in &prog.trace.insts {
            chain.push(*inst);
        }
    }
    // 2. §IV-G2: consecutive layers can skip SetIVNLayout when the previous
    // layer's SetOVNLayout already describes the layout. (For illustration,
    // make the layouts agree, then elide.)
    let mut demo = Trace::new();
    let shared = minisa::layout::VnLayout::new(1, 16, 16, 8, 16);
    for li in 0..3 {
        demo.begin_layer();
        demo.push(Inst::SetIVNLayout(LayoutInst { layout: shared }));
        demo.push(Inst::SetWVNLayout(LayoutInst { layout: shared }));
        demo.push(Inst::SetOVNLayout(LayoutInst { layout: shared }));
        let _ = li;
    }
    let before = demo.len();
    let elided = demo.elide_interlayer_layouts();
    println!(
        "\nconsecutive-layer elision: {before} → {} instructions ({elided} SetIVNLayout skipped, §IV-G2)",
        demo.len()
    );
    println!(
        "chain totals: {} B MINISA vs {} B micro-instructions ({:.0}×)\n",
        total_minisa,
        total_micro,
        total_micro as f64 / total_minisa.max(1) as f64
    );

    // 3. Serve decode-style batched requests through the runtime.
    let executor: Arc<dyn TileExecutor> =
        match minisa::runtime::PjrtExecutor::start(std::path::Path::new("artifacts")) {
            Ok(e) => {
                println!("serving on PJRT ({})", e.platform());
                Arc::new(e)
            }
            Err(e) => {
                println!("PJRT unavailable ({e:#}); serving on the naive executor");
                Arc::new(NaiveExecutor)
            }
        };
    let (tx, rx, h) = spawn(&cfg, executor);
    let mut rng = Lcg::new(17);
    let weight = rng.f32_matrix(64, 64); // shared per-layer weight (decode)
    let n_req = 32;
    let wall = std::time::Instant::now();
    for id in 0..n_req {
        tx.send(Request {
            id,
            m: 16, // one decode micro-batch row block
            k: 64,
            n: 64,
            input: rng.f32_matrix(16, 64),
            weight: weight.clone(),
        })?;
    }
    let mut lat = Vec::new();
    for _ in 0..n_req {
        lat.push(rx.recv()?.service_us);
    }
    drop(tx);
    let stats = h.join().unwrap();
    let wall_us = wall.elapsed().as_secs_f64() * 1e6;
    println!(
        "served {} requests in {:.1} ms: p50 {:.0} µs, p99 {:.0} µs, {} batches (max batch {}), {:.0} req/s",
        stats.served,
        wall_us / 1e3,
        percentile(&lat, 50.0),
        percentile(&lat, 99.0),
        stats.batches,
        stats.max_batch,
        stats.throughput_per_s(wall_us),
    );
    Ok(())
}
