//! Domain example — GPT-oss-20B inference layers (§VI workloads), the
//! dynamic-operand case FEATHER+ was refined for: both operands arrive at
//! runtime, so FEATHER's pre-known-weight offline reorder does not apply.
//!
//! Compiles the 3-layer MLP slice of the model into a **Program** — one
//! chain-aware mapper pass, the fused §IV-G multi-layer trace with the
//! consecutive-layer `SetIVNLayout` elision (§IV-G2), and precompiled wave
//! plans — then serves decode-style activation-only requests through a
//! registered model session (PJRT runtime when artifacts are available).
//!
//! ```sh
//! cargo run --release --example llm_gpt_oss
//! ```

use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::coordinator::serve::{spawn, NaiveExecutor, Request, TileExecutor};
use minisa::mapper::chain::Chain;
use minisa::mapper::search::MapperOptions;
use minisa::program::Program;
use minisa::util::{percentile, Lcg};
use minisa::workloads;

fn main() -> anyhow::Result<()> {
    let cfg = ArchConfig::paper(16, 64);
    // A GPT-oss-like MLP slice: 2880 → 5120 → 2880 → 2048 (Tab. IV shapes),
    // with a short sequence so the example runs quickly.
    let chain = Chain::mlp("gpt_oss_mlp", 256, &workloads::gpt_oss_mlp_dims());
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };

    // 1. Compile the whole chain once: per-layer decisions under the §V-A
    // boundary-compatibility rule, fused trace, wave plans.
    let program = Program::compile(&cfg, &chain, &opts)
        .ok_or_else(|| anyhow::anyhow!("no mapping for the GPT-oss chain"))?;
    for l in &program.layers {
        let (g, d) = (&l.gemm, &l.decision);
        println!(
            "{:<16} M={} K={} N={}: df {:?}, tile ({},{},{}), util {:.1}%, {} insts, {} B MINISA / {} B micro",
            g.name, g.m, g.k, g.n, d.choice.df, d.choice.m_t, d.choice.k_t, d.choice.n_t,
            d.report.utilization() * 100.0,
            l.lowered.trace.len(),
            l.lowered.minisa_bytes(),
            l.lowered.micro_bytes(),
        );
    }
    // 2. §IV-G2 in the compiled artifact: consecutive layers alternate
    // dataflow, so layer i's committed output layout is what layer i+1
    // consumes — the successor's SetIVNLayout is redundant and elided.
    println!(
        "\nprogram: {} layers fused into one {}-instruction trace, {} SetIVNLayout elided (§IV-G2)",
        program.layer_count(),
        program.fused.len(),
        program.elided,
    );
    println!(
        "chain totals: {} B fused MINISA ({} B standalone), {} wave plans precompiled, modeled {:.0} cycles/pass\n",
        program.fused_bytes, program.standalone_bytes, program.plan_count(), program.total_cycles,
    );

    // 3. Serve decode-style batched requests through a model session: the
    // chain compiles once at registration; every request carries only its
    // activation and batches with same-program neighbours.
    let executor: Arc<dyn TileExecutor> =
        match minisa::runtime::PjrtExecutor::start(std::path::Path::new("artifacts")) {
            Ok(e) => {
                println!("serving on PJRT ({})", e.platform());
                Arc::new(e)
            }
            Err(e) => {
                println!("PJRT unavailable ({e:#}); serving on the naive executor");
                Arc::new(NaiveExecutor)
            }
        };
    let (tx, rx, h, server) = spawn(&cfg, executor);
    let mut rng = Lcg::new(17);
    // A decode-scale session (16 rows/request) so the naive fallback stays
    // fast; the registration-time compile is the same machinery as above.
    let decode = Chain::mlp("decode_mlp", 16, &[64, 128, 64]);
    let weights: Vec<Vec<f32>> = decode.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
    let pid = server.register_chain(&decode, weights)?;
    let n_req = 32;
    let wall = std::time::Instant::now();
    for id in 0..n_req {
        tx.send(Request::for_program(id, pid, 16, rng.f32_matrix(16, 64)))?;
    }
    let mut lat = Vec::new();
    for _ in 0..n_req {
        let r = rx.recv()?;
        anyhow::ensure!(r.error.is_none(), "request {}: {}", r.id, r.error.unwrap_or_default());
        lat.push(r.service_us);
    }
    drop(tx);
    let stats = h.join().unwrap();
    let wall_us = wall.elapsed().as_secs_f64() * 1e6;
    println!(
        "served {} program requests in {:.1} ms: p50 {:.0} µs, p99 {:.0} µs, {} batches (max batch {}), \
         {:.0} req/s, {} chain compile(s)",
        stats.program_served,
        wall_us / 1e3,
        percentile(&lat, 50.0),
        percentile(&lat, 99.0),
        stats.batches,
        stats.max_batch,
        stats.throughput_per_s(wall_us),
        stats.program_compiles,
    );
    Ok(())
}
