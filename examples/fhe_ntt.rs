//! Domain example — FHE & ZKP kernels (§VI workloads).
//!
//! NTT and BConv GEMMs have shapes that rigid accelerators hate (K=40,
//! N=88, tall-skinny NTT matrices). This example sweeps the cryptography
//! workloads over three FEATHER+ scales and reports what the paper's
//! evaluation reports: utilization, MINISA-vs-micro speedup and
//! instruction-traffic reduction, plus the rigid-systolic comparison.
//!
//! ```sh
//! cargo run --release --example fhe_ntt
//! ```

use minisa::arch::ArchConfig;
use minisa::baselines;
use minisa::coordinator::evaluate_one;
use minisa::mapper::search::MapperOptions;
use minisa::report::{eng, f2, pct, Table};
use minisa::workloads;

fn main() -> anyhow::Result<()> {
    let mut ws = workloads::fhe_bconv().into_iter().step_by(10).collect::<Vec<_>>();
    ws.extend(workloads::fhe_ntt());
    ws.extend(workloads::zkp_ntt().into_iter().take(2));
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };

    for (ah, aw) in [(4usize, 16usize), (8, 32), (16, 64)] {
        let cfg = ArchConfig::paper(ah, aw);
        let mut t = Table::new(
            &format!("FHE/ZKP kernels on FEATHER+ {}", cfg.name()),
            &["workload", "M", "K", "N", "util(F+)", "util(systolic)", "speedup", "instr_red"],
        );
        for g in &ws {
            let Some(row) = evaluate_one(&cfg, g, &opts) else { continue };
            t.row(vec![
                g.name.clone(),
                g.m.to_string(),
                g.k.to_string(),
                g.n.to_string(),
                pct(row.decision.report.utilization()),
                pct(baselines::rigid_systolic().utilization(g)),
                f2(row.speedup()),
                eng(row.instr_reduction()),
            ]);
        }
        println!("{}", t.render());
    }
    println!(
        "Takeaway (§VI-C2): FEATHER+ sustains high utilization on K=40/N=88-class shapes\n\
         where a rigid 256×256 systolic array drops to a few percent; MINISA keeps the\n\
         flexibility essentially free of instruction traffic."
    );
    Ok(())
}
