//! Quickstart: map one GEMM onto FEATHER+, inspect the MINISA program, and
//! verify it computes the right answer in the functional simulator.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use minisa::arch::ArchConfig;
use minisa::mapper::exec::validate_decision;
use minisa::mapper::search::{search, MapperOptions};
use minisa::mapper::lower_gemm;
use minisa::workloads::Gemm;

fn main() -> anyhow::Result<()> {
    // A 4×4 FEATHER+ (AH=4 PE rows → 4-element Virtual Neurons).
    let cfg = ArchConfig::paper(4, 4);
    // An intentionally awkward GEMM: nothing divides anything.
    let g = Gemm::new("quickstart", "demo", 30, 22, 18);

    println!("workload: {g}");
    println!("config:   FEATHER+ {} (D={} rows, {} PEs)\n", cfg.name(), cfg.d(), cfg.pes());

    // 1. (mapping, layout) co-search — §V.
    let d = search(&cfg, &g, &MapperOptions::default())
        .ok_or_else(|| anyhow::anyhow!("no feasible mapping"))?;
    println!(
        "mapper decision: dataflow {:?}, VN={}, tile ({},{},{}), nbc={}, dup={}, orders (I={}, W={}, O={})",
        d.choice.df, d.choice.vn, d.choice.m_t, d.choice.k_t, d.choice.n_t,
        d.choice.nbc, d.choice.dup, d.i_order, d.w_order, d.o_order,
    );
    println!(
        "estimated {} cycles, utilization {:.1}%, instruction-fetch stall {:.2}%\n",
        d.report.total_cycles,
        d.report.utilization() * 100.0,
        d.report.instr_stall_fraction() * 100.0
    );

    // 2. Deterministic lowering to the eight-instruction MINISA trace.
    let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    println!("{}", prog.trace.disassemble());
    println!(
        "{} instructions = {} bytes; the micro-instruction twin needs {} bytes ({:.0}× more)\n",
        prog.trace.len(),
        prog.minisa_bytes(),
        prog.micro_bytes(),
        prog.instr_reduction()
    );

    // 3. Execute the trace on real data in the functional simulator.
    let (got, expect) = validate_decision(&cfg, &g, &prog, 1234)?;
    anyhow::ensure!(got == expect, "functional mismatch");
    println!("functional simulation == naive GEMM for all {} outputs ✓", got.len());
    Ok(())
}
