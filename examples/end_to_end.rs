//! END-TO-END DRIVER — proves all layers compose on a real workload.
//!
//! Pipeline exercised (and cross-checked) in one run:
//!
//! 1. **Program compilation (L3)**: a 2-layer FHE-BConv chain (the Table I
//!    tile shape feeding a projection) compiled into a model Program —
//!    chain-aware (mapping, layout) co-search with §V-A boundary
//!    compatibility, fused §IV-G trace, precompiled wave plans.
//! 2. **Lowering → MINISA traces**: deterministic Eq.-(1) lowering per
//!    layer, fused with the §IV-G2 elision accounting.
//! 3. **Whole-program functional simulation**: the compiled program runs on
//!    real int8 operands through buffers / NEST / BIRRD / OB — every tile
//!    through the program's precompiled wave plans (zero runtime plan
//!    compiles) — and must equal the chained naive reference exactly.
//! 4. **AOT oracle (L1+L2 via PJRT)**: layer 0 runs through the
//!    JAX/Pallas-lowered HLO artifact on the PJRT CPU client — Python is
//!    not involved at runtime.
//! 5. **Cross-check**: simulator output == naive GEMM == PJRT oracle.
//! 6. **Headline metrics**: the paper's instruction-traffic reduction and
//!    speedup on a suite slice, per config — the Fig. 10/12 numbers at
//!    example scale, recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use minisa::arch::ArchConfig;
use minisa::arith::{encode_words, ElemType};
use minisa::artifact::WeightsPayload;
use minisa::coordinator::{evaluate_suite, summarize_by_config};
use minisa::functional::{naive_gemm, FunctionalSim};
use minisa::mapper::chain::Chain;
use minisa::mapper::search::{searches_run, MapperOptions};
use minisa::program::Program;
use minisa::report::{eng, f2, pct, Table};
use minisa::runtime::{gemm_via_tiles, Runtime};
use minisa::util::Lcg;
use minisa::workloads;

fn main() -> anyhow::Result<()> {
    println!("=== MINISA / FEATHER+ end-to-end driver ===\n");

    // ------------------------------------------------------------------
    // Stage 1-3: chain program → fused trace → whole-program simulation.
    // A BConv-shaped slice (K=40, N=88 — the Table I workload's tile)
    // feeding an 88→24 projection.
    let cfg = ArchConfig::paper(4, 4);
    let chain = Chain::mlp("bconv_chain", 64, &[40, 88, 24]);
    let opts = MapperOptions::default();
    let program = Program::compile(&cfg, &chain, &opts)
        .ok_or_else(|| anyhow::anyhow!("no mapping for the chain"))?;
    for l in &program.layers {
        println!(
            "[1] mapper: {} on {} → df {:?}, tile ({},{},{}), nbc {}, dup {}",
            l.gemm, cfg.name(), l.decision.choice.df, l.decision.choice.m_t,
            l.decision.choice.k_t, l.decision.choice.n_t, l.decision.choice.nbc,
            l.decision.choice.dup
        );
    }
    println!(
        "[2] lowering: {} fused MINISA instructions = {} bytes ({} B standalone, {} SetIVNLayout \
         elided §IV-G2; micro twin: {} bytes)",
        program.fused.len(),
        program.fused_bytes,
        program.standalone_bytes,
        program.elided,
        program.layers.iter().map(|l| l.lowered.micro_bytes()).sum::<u64>(),
    );

    let mut rng = Lcg::new(2026);
    let input: Vec<i32> =
        (0..program.rows() * program.in_features()).map(|_| rng.range(0, 9) as i32 - 4).collect();
    let weights: Vec<Vec<i32>> = chain
        .layers
        .iter()
        .map(|g| (0..g.k * g.n).map(|_| rng.range(0, 9) as i32 - 4).collect())
        .collect();
    let mut sim = FunctionalSim::new(&cfg);
    let sim_out = program
        .execute_i32(&mut sim, &input, &weights)
        .map_err(|e| anyhow::anyhow!("functional sim: {e}"))?;
    let reference = program.reference_i32(&input, &weights);
    anyhow::ensure!(sim_out == reference, "simulator disagrees with chained naive GEMM");
    anyhow::ensure!(sim.plan_compiles == 0, "program plans were not reused");
    println!(
        "[3] whole-program simulation: {} outputs exact vs chained naive GEMM, {} precompiled \
         wave plans, 0 runtime plan compiles ✓",
        sim_out.len(),
        program.plan_count()
    );

    // ------------------------------------------------------------------
    // Stage 3b: the deployable artifact — the encoded instruction stream
    // as the canonical program. Compile → save → load in-place; the loaded
    // program must execute bit-identically with ZERO mapper runs.
    let payload = WeightsPayload::owned(
        ElemType::I32,
        weights.iter().map(|w| encode_words::<i32>(w)).collect(),
    );
    let artifact = program
        .to_artifact(Some(payload))
        .map_err(|e| anyhow::anyhow!("artifact build: {e}"))?;
    let art_path = std::env::temp_dir().join("minisa_end_to_end.minisa");
    let container_bytes = artifact.to_bytes();
    std::fs::write(&art_path, &container_bytes)?;
    let loaded_art = minisa::artifact::Artifact::load(&art_path)
        .map_err(|e| anyhow::anyhow!("artifact load: {e}"))?;
    let searches_before = searches_run();
    let loaded = Program::from_artifact(&loaded_art)
        .map_err(|e| anyhow::anyhow!("artifact → program: {e}"))?;
    anyhow::ensure!(searches_run() == searches_before, "artifact load ran the mapper");
    let mut sim2 = FunctionalSim::new(&cfg);
    let loaded_out = loaded
        .execute_i32(&mut sim2, &input, &weights)
        .map_err(|e| anyhow::anyhow!("loaded program: {e}"))?;
    anyhow::ensure!(loaded_out == sim_out, "loaded program diverges from compiled program");
    anyhow::ensure!(sim2.plan_compiles == 0, "loaded program compiled plans at runtime");
    std::fs::remove_file(&art_path).ok();
    println!(
        "[3b] artifact: {} B container ({} B encoded trace) saved, loaded back with byte \
         fidelity verified, 0 mapper runs, bit-identical execution ✓",
        container_bytes.len(),
        artifact.trace_bytes.len(),
    );

    // ------------------------------------------------------------------
    // Stage 4-5: the AOT JAX/Pallas oracle through PJRT (layer 0).
    let g0 = &chain.layers[0];
    let l0_ref = naive_gemm(&input, &weights[0], g0.m, g0.k, g0.n);
    match Runtime::open(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            println!(
                "[4] PJRT runtime up on '{}' with {} artifacts",
                rt.platform(),
                rt.artifacts().len()
            );
            let xf: Vec<f32> = input.iter().map(|&v| v as f32).collect();
            let wf: Vec<f32> = weights[0].iter().map(|&v| v as f32).collect();
            let oracle = gemm_via_tiles(&rt, g0.m, g0.k, g0.n, &xf, &wf)?;
            let mut max_err = 0f64;
            for (a, b) in oracle.iter().zip(&l0_ref) {
                max_err = max_err.max((*a as f64 - *b as f64).abs());
            }
            anyhow::ensure!(
                max_err < 1e-3,
                "PJRT oracle mismatch: max |err| = {max_err}"
            );
            println!(
                "[5] cross-check: functional sim == naive GEMM == Pallas/JAX HLO oracle \
                 (max |err| {max_err:.1e}) ✓"
            );
        }
        Err(e) => {
            println!("[4] PJRT oracle skipped (artifacts not built?): {e:#}");
            println!("    run `make artifacts` first for the full cross-check");
        }
    }

    // ------------------------------------------------------------------
    // Stage 6: headline metrics on a suite slice × three scales.
    println!("\n[6] headline metrics (suite slice — full run: `minisa evaluate`):\n");
    let ws = workloads::suite_small();
    let cfgs = vec![
        ArchConfig::paper(4, 4),
        ArchConfig::paper(8, 32),
        ArchConfig::paper(16, 256),
    ];
    let fast = MapperOptions { full_layout_search: false, ..Default::default() };
    let rows = evaluate_suite(&cfgs, &ws, &fast, 8);
    let mut t = Table::new(
        "MINISA vs micro-instruction control (geomean over suite slice)",
        &["config", "speedup", "instr_reduction", "micro_stall", "minisa_stall", "utilization"],
    );
    for s in summarize_by_config(&rows) {
        t.row(vec![
            s.config,
            f2(s.geo_speedup),
            eng(s.geo_instr_reduction),
            pct(s.mean_stall_micro),
            pct(s.mean_stall_minisa),
            pct(s.mean_utilization),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Shape check vs paper: speedup ≈1× at 4×4 growing to tens of × at 16×256 (Fig. 10);\n\
         instruction reduction grows to ~10⁴–10⁵× (Fig. 12); micro stalls ~97% at 16×256 (Tab. I)."
    );
    Ok(())
}
