//! END-TO-END DRIVER — proves all layers compose on a real workload.
//!
//! Pipeline exercised (and cross-checked) in one run:
//!
//! 1. **Mapper (L3)**: (mapping, layout) co-search for a real FHE-BConv
//!    GEMM shape on FEATHER+ 4×4 — §V.
//! 2. **Lowering → MINISA trace**: deterministic Eq.-(1) lowering — §V-B7.
//! 3. **Functional simulation**: the trace executes on real int8 operands
//!    through buffers / NEST / BIRRD / OB — §IV-G semantics.
//! 4. **AOT oracle (L1+L2 via PJRT)**: the same GEMM runs through the
//!    JAX/Pallas-lowered HLO artifact on the PJRT CPU client — Python is
//!    not involved at runtime.
//! 5. **Cross-check**: simulator output == naive GEMM == PJRT oracle.
//! 6. **Headline metrics**: the paper's instruction-traffic reduction and
//!    speedup on a suite slice, per config — the Fig. 10/12 numbers at
//!    example scale, recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use minisa::arch::ArchConfig;
use minisa::coordinator::{evaluate_suite, summarize_by_config};
use minisa::functional::naive_gemm;
use minisa::mapper::exec::execute_program;
use minisa::mapper::search::{search, MapperOptions};
use minisa::mapper::lower_gemm;
use minisa::report::{eng, f2, pct, Table};
use minisa::runtime::{gemm_via_tiles, Runtime};
use minisa::util::Lcg;
use minisa::workloads::{self, Gemm};

fn main() -> anyhow::Result<()> {
    println!("=== MINISA / FEATHER+ end-to-end driver ===\n");

    // ------------------------------------------------------------------
    // Stage 1-3: mapper → trace → functional simulation on real data.
    // A BConv-shaped slice (K=40, N=88 — the Table I workload's tile).
    let cfg = ArchConfig::paper(4, 4);
    let g = Gemm::new("bconv_slice", "FHE-BConv", 64, 40, 88);
    let opts = MapperOptions::default();
    let d = search(&cfg, &g, &opts).ok_or_else(|| anyhow::anyhow!("no mapping"))?;
    let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    println!(
        "[1] mapper: {g} on {} → df {:?}, tile ({},{},{}), nbc {}, dup {}",
        cfg.name(), d.choice.df, d.choice.m_t, d.choice.k_t, d.choice.n_t,
        d.choice.nbc, d.choice.dup
    );
    println!(
        "[2] lowering: {} MINISA instructions = {} bytes (micro twin: {} bytes, {}×)",
        prog.trace.len(),
        prog.minisa_bytes(),
        prog.micro_bytes(),
        eng(prog.instr_reduction())
    );

    let mut rng = Lcg::new(2026);
    let iv: Vec<i32> = (0..g.m * g.k).map(|_| rng.range(0, 9) as i32 - 4).collect();
    let wv: Vec<i32> = (0..g.k * g.n).map(|_| rng.range(0, 9) as i32 - 4).collect();
    let sim_out = execute_program(&cfg, &g, &prog, &iv, &wv)
        .map_err(|e| anyhow::anyhow!("functional sim: {e}"))?;
    let reference = naive_gemm(&iv, &wv, g.m, g.k, g.n);
    anyhow::ensure!(sim_out == reference, "simulator disagrees with naive GEMM");
    println!("[3] functional simulation: {} outputs exact vs naive GEMM ✓", sim_out.len());

    // ------------------------------------------------------------------
    // Stage 4-5: the AOT JAX/Pallas oracle through PJRT.
    match Runtime::open(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            println!(
                "[4] PJRT runtime up on '{}' with {} artifacts",
                rt.platform(),
                rt.artifacts().len()
            );
            let xf: Vec<f32> = iv.iter().map(|&v| v as f32).collect();
            let wf: Vec<f32> = wv.iter().map(|&v| v as f32).collect();
            let oracle = gemm_via_tiles(&rt, g.m, g.k, g.n, &xf, &wf)?;
            let mut max_err = 0f64;
            for (a, b) in oracle.iter().zip(&reference) {
                max_err = max_err.max((*a as f64 - *b as f64).abs());
            }
            anyhow::ensure!(
                max_err < 1e-3,
                "PJRT oracle mismatch: max |err| = {max_err}"
            );
            println!(
                "[5] cross-check: functional sim == naive GEMM == Pallas/JAX HLO oracle \
                 (max |err| {max_err:.1e}) ✓"
            );
        }
        Err(e) => {
            println!("[4] PJRT oracle skipped (artifacts not built?): {e:#}");
            println!("    run `make artifacts` first for the full cross-check");
        }
    }

    // ------------------------------------------------------------------
    // Stage 6: headline metrics on a suite slice × three scales.
    println!("\n[6] headline metrics (suite slice — full run: `minisa evaluate`):\n");
    let ws = workloads::suite_small();
    let cfgs = vec![
        ArchConfig::paper(4, 4),
        ArchConfig::paper(8, 32),
        ArchConfig::paper(16, 256),
    ];
    let fast = MapperOptions { full_layout_search: false, ..Default::default() };
    let rows = evaluate_suite(&cfgs, &ws, &fast, 8);
    let mut t = Table::new(
        "MINISA vs micro-instruction control (geomean over suite slice)",
        &["config", "speedup", "instr_reduction", "micro_stall", "minisa_stall", "utilization"],
    );
    for s in summarize_by_config(&rows) {
        t.row(vec![
            s.config,
            f2(s.geo_speedup),
            eng(s.geo_instr_reduction),
            pct(s.mean_stall_micro),
            pct(s.mean_stall_minisa),
            pct(s.mean_utilization),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Shape check vs paper: speedup ≈1× at 4×4 growing to tens of × at 16×256 (Fig. 10);\n\
         instruction reduction grows to ~10⁴–10⁵× (Fig. 12); micro stalls ~97% at 16×256 (Tab. I)."
    );
    Ok(())
}
