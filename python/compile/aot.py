"""AOT lowering: JAX/Pallas (Layers 1-2) -> HLO text artifacts for the Rust
PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Artifact registry: name -> (fn, example args). Shapes are compute-tile
# sized; the Rust coordinator tiles larger problems onto these executables.
ARTIFACTS = {
    # Square aligned tile (the quickstart / serving path).
    "gemm_64x64x64": (model.gemm_tile, [spec(64, 64), spec(64, 64)]),
    # Irregular FHE-BConv-shaped tile (Table I workload tile: K=40, N=88).
    "gemm_64x40x88": (model.gemm_tile, [spec(64, 40), spec(40, 88)]),
    # Wider serving tile for batched requests.
    "gemm_128x64x64": (model.gemm_tile, [spec(128, 64), spec(64, 64)]),
    # One full layer with activation.
    "layer_relu_64x64x64": (model.layer_relu, [spec(64, 64), spec(64, 64)]),
    # Consecutive-layer chain (SIV-G2).
    "chain_32x64x48x32": (
        model.two_layer_chain,
        [spec(32, 64), spec(64, 48), spec(48, 32)],
    ),
    # Attention scores (dynamic-operand workload class).
    "attn_64x64": (model.attention_scores, [spec(64, 64), spec(64, 64)]),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, specs) in ARTIFACTS.items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(s.shape) for s in specs],
            "dtype": "f32",
            "hlo_chars": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    mpath = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest when lowering a single artifact.
    if args.only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old.update(manifest)
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
