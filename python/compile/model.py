"""Layer 2 - the JAX compute graph lowered to the AOT artifacts.

Three exported entry points (all calling the Layer-1 Pallas kernel):

* ``gemm_tile``      - one GEMM compute tile (the runtime oracle for the
                       functional simulator's outputs);
* ``layer_relu``     - GEMM + ReLU (one FEATHER+ layer incl. Activation);
* ``two_layer_chain``- two chained layers, the SIV-G2 consecutive-layer
                       execution (output of layer i = input of layer i+1,
                       OB -> operand-buffer commit path).

Python runs only at build time; the Rust runtime executes the lowered HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.nest_gemm import nest_gemm, nest_gemm_relu


def gemm_tile(x, w):
    """One compute tile executed with the NEST kernel structure."""
    return (nest_gemm(x, w, vn=16, block_m=64, block_n=64),)


def layer_relu(x, w):
    """One full layer: GEMM + Activation(ReLU)."""
    return (nest_gemm_relu(x, w, vn=16, block_m=64, block_n=64),)


def two_layer_chain(x, w1, w2):
    """Consecutive layers: SetOVNLayout of layer 1 doubles as SetIVNLayout
    of layer 2 (SIV-G2); numerically this is layer2(relu(layer1(x)))."""
    h = nest_gemm_relu(x, w1, vn=16, block_m=64, block_n=64)
    return (nest_gemm(h, w2, vn=16, block_m=64, block_n=64),)


def attention_scores(q, kmat):
    """GPT-oss-style attention-score GEMM (Q . K^T scaled): the workload
    class motivating dynamic-input support in FEATHER+ (SII-C) - both
    operands arrive at runtime, neither can be offline-reordered."""
    d = q.shape[-1]
    return (nest_gemm(q, kmat.T, vn=16, block_m=64, block_n=64) / jnp.sqrt(jnp.float32(d)),)
