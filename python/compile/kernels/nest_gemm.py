"""Layer 1 — the NEST compute atom as a Pallas kernel.

The kernel mirrors FEATHER+'s execution structure (§III-A / §IV):

* the grid walks (M-tile, N-tile) pairs — one grid step is one *compute
  tile* (an ExecuteMapping/ExecuteStreaming invocation group);
* each step keeps a ``(BM, K)`` streamed block and a ``(K, BN)`` stationary
  block resident in VMEM (the scratchpad analogue of the streaming /
  stationary buffers feeding PE-local registers);
* inside the kernel the reduction axis is consumed in AH-element Virtual
  Neuron chunks via ``jax.lax.fori_loop``, accumulating partial sums exactly
  like the per-PE AH-element dot product + output-buffer temporal reduction
  (three-level reduction, §III-C1a).

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): FEATHER+'s
streaming/stationary buffers map to VMEM-resident blocks via BlockSpec; the
per-PE dot-product atom maps to an MXU-shaped ``jnp.dot`` over the VN chunk;
BIRRD's reorder-in-reduction has no MXU analogue so layout flexibility is
realized at the BlockSpec index level. ``interpret=True`` everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nest_kernel(x_ref, w_ref, o_ref, *, vn: int, k: int):
    """One compute tile: (BM, K) × (K, BN) → (BM, BN).

    The fori_loop consumes the reduction axis VN-by-VN: iteration ``g``
    computes the AH-element dot product every PE would perform for VN row
    ``g`` and accumulates into the output tile (OB temporal reduction).
    """
    kg = (k + vn - 1) // vn

    def body(g, acc):
        x_vn = jax.lax.dynamic_slice_in_dim(x_ref[...], g * vn, vn, axis=1)
        w_vn = jax.lax.dynamic_slice_in_dim(w_ref[...], g * vn, vn, axis=0)
        # The VN atom: AH-length dot product, MXU-friendly f32 accumulate.
        return acc + jnp.dot(
            x_vn.astype(jnp.float32),
            w_vn.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    acc0 = jnp.zeros(o_ref.shape, jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, kg, body, acc0)


def nest_gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    vn: int = 16,
    block_m: int = 64,
    block_n: int = 64,
) -> jax.Array:
    """FEATHER+-structured GEMM: ``O[M, N] = x[M, K] · w[K, N]``.

    ``vn`` is the Virtual Neuron length (AH); ``block_m``/``block_n`` are the
    compute-tile extents (the mapper's M_t / N_t knobs). K must already be a
    multiple of ``vn`` or it is zero-padded here (the ISA's implicit
    zero-padding rule, §IV-C2).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"reduction mismatch {k} vs {k2}"
    pad_k = (-k) % vn
    pad_m = (-m) % block_m
    pad_n = (-n) % block_n
    xp = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // block_m, np_ // block_n)
    out = pl.pallas_call(
        functools.partial(_nest_kernel, vn=vn, k=kp),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=grid,
        in_specs=[
            # Streamed block: new M-tile per grid row, full K resident.
            pl.BlockSpec((block_m, kp), lambda i, j: (i, 0)),
            # Stationary block: full K × N-tile, reused across the M walk —
            # the weight-stationary reuse of WO-S.
            pl.BlockSpec((kp, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        interpret=True,  # CPU path; real-TPU lowering emits Mosaic calls
    )(xp, wp)
    return out[:m, :n]


def nest_gemm_relu(x: jax.Array, w: jax.Array, **kw) -> jax.Array:
    """GEMM + ReLU (the Activation supporting instruction)."""
    return jnp.maximum(nest_gemm(x, w, **kw), 0.0)


def vmem_footprint_bytes(
    k: int, *, block_m: int = 64, block_n: int = 64, elem_bytes: int = 4
) -> int:
    """Estimated VMEM residency of one grid step: streamed block +
    stationary block + output tile. Used by the §Perf structural analysis
    (interpret mode has no real VMEM)."""
    return elem_bytes * (block_m * k + k * block_n + block_m * block_n)
