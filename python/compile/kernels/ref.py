"""Pure-jnp reference oracle for the NEST GEMM kernel.

This is the correctness ground truth for Layer 1: ``nest_gemm`` (the Pallas
kernel) must match ``ref_gemm`` exactly (integer inputs) / to float tolerance
on every shape the test sweep generates, and the Rust functional simulator is
cross-checked against the same semantics through the AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_gemm(x, w):
    """O[M, N] = I[M, K] . W[K, N] with f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def ref_gemm_relu(x, w):
    """GEMM followed by the Activation instruction's ReLU."""
    return jnp.maximum(ref_gemm(x, w), 0.0)


def ref_two_layer(x, w1, w2):
    """Two chained layers (SIV-G2 consecutive-layer trace): the output of
    layer 1 (post-ReLU) is the input of layer 2, exactly the OB->operand
    buffer path of FEATHER+."""
    return ref_gemm(ref_gemm_relu(x, w1), w2)


def ref_vn_decomposed(x, w, vn: int):
    """GEMM computed the way FEATHER+ does: the reduction axis is split into
    AH-element Virtual Neurons, each VN contributes one partial sum, and
    psums accumulate in the output buffer. Must equal ``ref_gemm`` exactly -
    this *is* the VN abstraction's correctness claim (SIV-B)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    pad = (-k) % vn
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, pad), (0, 0)))
    kg = (k + pad) // vn
    # One dot product per (m, n, VN row) - the per-PE atom.
    xr = xp.reshape(m, kg, vn)
    wr = wp.reshape(kg, vn, n)
    psums = jnp.einsum("mgv,gvn->gmn", xr, wr)
    return psums.sum(axis=0)
