"""AOT pipeline tests: every registry entry lowers to parseable HLO text
with the expected entry signature; the manifest is consistent."""

import json
import os

import jax
import pytest

from compile import aot


@pytest.mark.parametrize("name", sorted(aot.ARTIFACTS))
def test_artifact_lowers_to_hlo_text(name):
    fn, specs = aot.ARTIFACTS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Tuple return (the rust side unwraps with to_tuple1).
    assert "tuple" in text or ")->(" in text.replace(" ", "")


def test_manifest_written(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--only", "gemm_64x64x64"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    m = json.load(open(tmp_path / "manifest.json"))
    assert m["gemm_64x64x64"]["args"] == [[64, 64], [64, 64]]
    assert os.path.exists(tmp_path / "gemm_64x64x64.hlo.txt")
