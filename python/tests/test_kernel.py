"""Layer-1 correctness: the Pallas NEST kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, VN sizes and block shapes; allclose
against ref.py is the core correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.nest_gemm import nest_gemm, nest_gemm_relu, vmem_footprint_bytes
from compile.kernels.ref import ref_gemm, ref_gemm_relu, ref_vn_decomposed


def rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == jnp.int8:
        return jnp.asarray(rng.integers(-8, 8, size=shape, dtype=np.int8))
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 80),
    n=st.integers(1, 96),
    vn=st.sampled_from([4, 8, 16]),
    data=st.integers(0, 2**31 - 1),
)
def test_nest_gemm_matches_ref_f32(m, k, n, vn, data):
    x = rand((m, k), jnp.float32, data)
    w = rand((k, n), jnp.float32, data + 1)
    got = nest_gemm(x, w, vn=vn, block_m=32, block_n=32)
    np.testing.assert_allclose(got, ref_gemm(x, w), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    vn=st.sampled_from([4, 16]),
    data=st.integers(0, 2**31 - 1),
)
def test_nest_gemm_exact_on_int8_operands(m, k, n, vn, data):
    """Integer operands must be bit-exact (f32 holds i8 x i8 sums exactly)."""
    x = rand((m, k), jnp.int8, data)
    w = rand((k, n), jnp.int8, data + 1)
    got = nest_gemm(x.astype(jnp.float32), w.astype(jnp.float32), vn=vn, block_m=32, block_n=32)
    expect = ref_gemm(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    data=st.integers(0, 2**31 - 1),
)
def test_relu_fusion(m, k, n, data):
    x = rand((m, k), jnp.float32, data)
    w = rand((k, n), jnp.float32, data + 1)
    got = nest_gemm_relu(x, w, vn=8, block_m=16, block_n=16)
    np.testing.assert_allclose(got, ref_gemm_relu(x, w), rtol=1e-5, atol=1e-5)
    assert (np.asarray(got) >= 0).all()


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 70),
    vn=st.sampled_from([2, 4, 8, 16]),
    data=st.integers(0, 2**31 - 1),
)
def test_vn_decomposition_is_exact(k, vn, data):
    """The VN abstraction itself: splitting the reduction into AH-chunks and
    accumulating psums changes nothing (SIV-B insight)."""
    x = rand((8, k), jnp.int8, data)
    w = rand((k, 12), jnp.int8, data + 1)
    a = ref_vn_decomposed(x, w, vn)
    b = ref_gemm(x, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("block", [16, 32, 64, 128])
def test_block_shape_invariance(block):
    """Mapper tile-size knob: any block shape gives identical numerics."""
    x = rand((70, 40), jnp.float32, 7)
    w = rand((40, 50), jnp.float32, 8)
    base = nest_gemm(x, w, vn=8, block_m=16, block_n=16)
    got = nest_gemm(x, w, vn=8, block_m=block, block_n=block)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_vn_larger_than_k_is_fine():
    x = rand((4, 3), jnp.float32, 1)
    w = rand((3, 4), jnp.float32, 2)
    got = nest_gemm(x, w, vn=16, block_m=4, block_n=4)
    np.testing.assert_allclose(got, ref_gemm(x, w), rtol=1e-5, atol=1e-5)


def test_vmem_footprint_model():
    # 64x64 tile over K=512 at f32: (64*512 + 512*64 + 64*64)*4 bytes.
    b = vmem_footprint_bytes(512, block_m=64, block_n=64)
    assert b == 4 * (64 * 512 + 512 * 64 + 64 * 64)
    # Must fit a 16 MiB VMEM budget for the default tile.
    assert vmem_footprint_bytes(2880) < 16 * 1024 * 1024


def test_jit_composes():
    """The kernel must lower inside jit (the AOT path requirement)."""
    f = jax.jit(lambda x, w: nest_gemm(x, w, vn=16, block_m=32, block_n=32))
    x = rand((32, 32), jnp.float32, 3)
    w = rand((32, 32), jnp.float32, 4)
    np.testing.assert_allclose(f(x, w), ref_gemm(x, w), rtol=1e-5, atol=1e-5)
