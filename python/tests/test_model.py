"""Layer-2 model tests: shapes, chaining semantics, attention scaling."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ref_gemm, ref_two_layer


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def test_gemm_tile_matches_ref():
    x, w = rand((64, 64), 0), rand((64, 64), 1)
    (o,) = model.gemm_tile(x, w)
    np.testing.assert_allclose(o, ref_gemm(x, w), rtol=1e-5, atol=1e-5)


def test_layer_relu_nonnegative():
    x, w = rand((64, 64), 2), rand((64, 64), 3)
    (o,) = model.layer_relu(x, w)
    assert (np.asarray(o) >= 0).all()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_two_layer_chain_semantics(seed):
    """Chain == layer2(relu(layer1(x))) — the SIV-G2 trace semantics."""
    x, w1, w2 = rand((32, 64), seed), rand((64, 48), seed + 1), rand((48, 32), seed + 2)
    (o,) = model.two_layer_chain(x, w1, w2)
    np.testing.assert_allclose(o, ref_two_layer(x, w1, w2), rtol=1e-4, atol=1e-4)


def test_attention_scores_scaled():
    q, k = rand((64, 64), 5), rand((64, 64), 6)
    (s,) = model.attention_scores(q, k)
    expect = np.asarray(ref_gemm(q, k.T)) / np.sqrt(64.0)
    np.testing.assert_allclose(s, expect, rtol=1e-5, atol=1e-5)
